//! Experiment E1 as a test: the simulator must track the paper's closed
//! forms (Eqs. 1–5) tightly on the idealised fabric — and reproduce the
//! *qualitative* claims of §III/§IV on the real KESCH topology.

use gdrbcast::analytic::{self, validate::run_grid, ModelParams};
use gdrbcast::collectives::{self, Algorithm, BcastSpec};
use gdrbcast::comm::{Comm, CommParams};
use gdrbcast::netsim::Engine;
use gdrbcast::topology::presets;

#[test]
fn full_grid_under_two_percent() {
    let algos = [
        Algorithm::Direct,
        Algorithm::Chain,
        Algorithm::PipelinedChain { chunk: 256 << 10 },
        Algorithm::Knomial { k: 2 },
        Algorithm::Knomial { k: 4 },
        Algorithm::Knomial { k: 8 },
    ];
    let rows = run_grid(
        &algos,
        &[2, 3, 4, 8, 16, 32, 64, 128],
        &[4, 512, 8 << 10, 1 << 20, 16 << 20, 128 << 20],
    );
    assert!(rows.len() > 200);
    for row in &rows {
        assert!(
            row.rel_err < 0.02,
            "{} n={} M={}: sim {} model {} err {:.4}",
            row.algorithm,
            row.n,
            row.bytes,
            row.sim_ns,
            row.model_ns,
            row.rel_err
        );
    }
}

#[test]
fn eq5_optimal_chunk_is_optimal_in_sim() {
    // the analytic C* = sqrt(M t_s B / (n-2)) must (approximately)
    // minimise the simulated pipelined-chain time on the flat fabric
    let n = 16;
    let m: u64 = 64 << 20;
    let cp = CommParams::default();
    let p = ModelParams::flat_rndv(&cp);
    let c_star = analytic::bcast::optimal_chunk(&p, n, m);
    let cluster = presets::flat(n).unwrap();
    let mut comm = Comm::new(&cluster);
    let mut engine = Engine::new(&cluster);
    let t = |chunk: u64, comm: &mut Comm, engine: &mut Engine| {
        collectives::latency_ns(
            &Algorithm::PipelinedChain { chunk },
            comm,
            engine,
            &BcastSpec::new(0, n, m),
        )
    };
    let t_star = t(c_star, &mut comm, &mut engine);
    for factor in [4u64, 16] {
        let worse_small = t(c_star / factor, &mut comm, &mut engine);
        let worse_big = t(c_star.saturating_mul(factor).min(m), &mut comm, &mut engine);
        assert!(t_star <= worse_small, "C*/{} beat C*", factor);
        assert!(t_star <= worse_big, "C**{} beat C*", factor);
    }
}

#[test]
fn paper_qualitative_claims_hold_on_kesch() {
    // §III/§IV qualitative structure on the real testbed model:
    let cluster = presets::kesch(2, 16).unwrap();
    let n = cluster.n_gpus();
    let mut comm = Comm::new(&cluster);
    let mut engine = Engine::new(&cluster);
    let lat = |algo: &Algorithm, bytes: u64, comm: &mut Comm, engine: &mut Engine| {
        collectives::latency_ns(algo, comm, engine, &BcastSpec::new(0, n, bytes))
    };

    // 1. direct is worst at scale (its O(n) serialisation)
    let m = 1 << 20;
    let direct = lat(&Algorithm::Direct, m, &mut comm, &mut engine);
    let knomial = lat(&Algorithm::Knomial { k: 2 }, m, &mut comm, &mut engine);
    assert!(direct > 3 * knomial, "direct {direct} vs knomial {knomial}");

    // 2. knomial beats chain for small messages (latency-bound)
    let small = 4096;
    let chain_s = lat(&Algorithm::Chain, small, &mut comm, &mut engine);
    let knomial_s = lat(&Algorithm::Knomial { k: 2 }, small, &mut comm, &mut engine);
    assert!(knomial_s < chain_s);

    // 3. pipelined chain beats knomial for very large messages
    //    (bandwidth-bound; the paper's motivating observation)
    let big = 128 << 20;
    let knomial_b = lat(&Algorithm::Knomial { k: 2 }, big, &mut comm, &mut engine);
    let pipe_b = lat(
        &Algorithm::PipelinedChain { chunk: 1 << 20 },
        big,
        &mut comm,
        &mut engine,
    );
    assert!(
        pipe_b * 2 < knomial_b,
        "pipelined {pipe_b} should crush knomial {knomial_b} at 128M"
    );

    // 4. host staging wins at tiny sizes, loses at huge ones (Eq. 6)
    let staged_tiny = lat(
        &Algorithm::HostStagedKnomial { k: 2 },
        4,
        &mut comm,
        &mut engine,
    );
    let knomial_tiny = lat(&Algorithm::Knomial { k: 2 }, 4, &mut comm, &mut engine);
    assert!(staged_tiny < knomial_tiny);
    let staged_huge = lat(
        &Algorithm::HostStagedKnomial { k: 2 },
        big,
        &mut comm,
        &mut engine,
    );
    assert!(pipe_b < staged_huge);
}

#[test]
fn route_interning_golden_parity() {
    // Route interning must be invisible to the simulation: for every
    // algorithm × message size × topology, the makespan is bit-identical
    // (a) across repeated executions against a warm route cache,
    // (b) on a freshly cloned cluster whose cache starts cold, and
    // (c) between the recording (`execute`) and makespan-only
    //     (`makespan_ns`) engine paths.
    let algos = [
        Algorithm::Direct,
        Algorithm::Chain,
        Algorithm::PipelinedChain { chunk: 64 << 10 },
        Algorithm::Knomial { k: 2 },
        Algorithm::Knomial { k: 4 },
        Algorithm::ScatterRingAllgather,
        Algorithm::HostStagedKnomial { k: 2 },
    ];
    let topologies: Vec<(&str, gdrbcast::topology::Cluster)> = vec![
        ("flat(8)", presets::flat(8).unwrap()),
        ("kesch(1,8)", presets::kesch(1, 8).unwrap()),
        ("kesch(2,8)", presets::kesch(2, 8).unwrap()),
    ];
    for (name, cluster) in &topologies {
        let n = cluster.n_gpus();
        let mut comm = Comm::new(cluster);
        let mut engine = Engine::new(cluster);
        for algo in &algos {
            for bytes in [4u64, 64 << 10, 16 << 20] {
                let spec = BcastSpec::new(0, n, bytes);
                let bp = collectives::plan(algo, &mut comm, &spec);
                let warm = engine.execute(&bp.plan).makespan;
                let warm_again = engine.execute(&bp.plan).makespan;
                let fast = engine.makespan_ns(&bp.plan);
                // cold cache: fresh cluster clone, fresh comm/engine
                let cold_cluster = cluster.clone();
                let mut cold_comm = Comm::new(&cold_cluster);
                let mut cold_engine = Engine::new(&cold_cluster);
                let cold_bp = collectives::plan(algo, &mut cold_comm, &spec);
                let cold = cold_engine.execute(&cold_bp.plan).makespan;
                let checks = [
                    ("warm-repeat", warm_again),
                    ("makespan-only", fast),
                    ("cold-cache", cold),
                ];
                for (label, t) in checks {
                    assert_eq!(
                        warm,
                        t,
                        "{} {} {}B: {label} diverged",
                        name,
                        algo.name(),
                        bytes
                    );
                }
            }
        }
        // the cache really interns: re-planning the whole menu must not
        // grow the route table
        let before = cluster.routes().n_routes();
        for algo in &algos {
            let spec = BcastSpec::new(0, n, 16 << 20);
            let _ = collectives::plan(algo, &mut comm, &spec);
        }
        assert_eq!(
            before,
            cluster.routes().n_routes(),
            "{name}: replanning interned new routes"
        );
    }
}

#[test]
fn plan_template_golden_parity() {
    // Plan templates must be invisible to the simulation: for every
    // algorithm × message size × topology, acquiring the plan through
    // the template cache (build once, rescale per size — ascending AND
    // descending so rescale runs both directions, with revisits hitting
    // the exact-size fast path) produces bit-identical makespans to a
    // fresh single-use build.
    use gdrbcast::collectives::{template, CollectiveSpec};

    let algos = [
        Algorithm::Direct,
        Algorithm::Chain,
        Algorithm::PipelinedChain { chunk: 64 << 10 },
        Algorithm::Knomial { k: 2 },
        Algorithm::Knomial { k: 4 },
        Algorithm::ScatterRingAllgather,
        Algorithm::HostStagedKnomial { k: 2 },
        Algorithm::RingReduceScatter,
        Algorithm::RingAllgather,
        Algorithm::RingAllreduce,
        Algorithm::TreeAllreduce { k: 2 },
    ];
    let topologies: Vec<(&str, gdrbcast::topology::Cluster)> = vec![
        ("flat(8)", presets::flat(8).unwrap()),
        ("kesch(1,8)", presets::kesch(1, 8).unwrap()),
        ("kesch(2,8)", presets::kesch(2, 8).unwrap()),
    ];
    let axis = [4u64, 4 << 10, 64 << 10, 1 << 20, 16 << 20];
    for (name, cluster) in &topologies {
        let n = cluster.n_gpus();
        let mut comm = Comm::new(cluster); // shared: templates warm across sizes
        let mut engine = Engine::new(cluster);
        let mut order: Vec<u64> = axis.to_vec();
        order.extend(axis.iter().rev());
        for algo in &algos {
            for &bytes in &order {
                let spec = CollectiveSpec::collective(algo.kind(), 0, n, bytes);
                let cached =
                    engine.makespan_ns(&template::cached_plan(algo, &mut comm, &spec).plan);
                let mut fresh_comm = Comm::new(cluster);
                let fresh = collectives::plan(algo, &mut fresh_comm, &spec);
                assert_eq!(
                    cached,
                    engine.makespan_ns(&fresh.plan),
                    "{} {} {}B: templated plan diverged from fresh build",
                    name,
                    algo.name(),
                    bytes
                );
            }
        }
        let (hits, misses) = comm.template_cache().stats();
        assert!(
            hits > misses,
            "{name}: the size axis should mostly rescale ({hits} hits / {misses} misses)"
        );
    }
}

#[test]
fn plan_template_cache_invalidated_by_topology_mutation() {
    // A template cache carried across a topology mutation must miss on
    // the bumped generation instead of serving plans whose interned
    // routes no longer exist (in debug builds a served stale plan would
    // also trip the RouteId generation check).
    use gdrbcast::collectives::CollectiveSpec;
    use gdrbcast::topology::LinkKind;

    let mut cluster = presets::kesch(1, 8).unwrap();
    let spec = CollectiveSpec::new(0, 8, 1 << 20);
    let algo = Algorithm::Knomial { k: 2 };
    let cache = {
        let mut comm = Comm::new(&cluster);
        let mut engine = Engine::new(&cluster);
        let _ = engine.makespan_ns(
            &gdrbcast::collectives::cached_plan(&algo, &mut comm, &spec).plan,
        );
        assert_eq!(comm.template_cache().stats().1, 1);
        comm.take_template_cache()
    };
    // mutation: a new NVLink between ranks 0 and 1 changes routing and
    // bumps the cluster generation
    let before = cluster.generation();
    let (g0, g1) = (cluster.rank_device(0), cluster.rank_device(1));
    cluster.connect(g0, g1, LinkKind::NvLink2);
    assert_ne!(before, cluster.generation());

    let mut comm = Comm::new(&cluster);
    comm.set_template_cache(cache);
    let mut engine = Engine::new(&cluster);
    let cached =
        engine.makespan_ns(&gdrbcast::collectives::cached_plan(&algo, &mut comm, &spec).plan);
    let mut fresh_comm = Comm::new(&cluster);
    let fresh = collectives::plan(&algo, &mut fresh_comm, &spec);
    assert_eq!(
        cached,
        engine.makespan_ns(&fresh.plan),
        "stale template served after topology mutation"
    );
    // the stale entry was swept, not rescaled: the post-mutation
    // acquisition must have been a rebuild
    let (hits, _) = comm.template_cache().stats();
    assert_eq!(hits, 0, "a cross-generation hit means stale structure");
}

#[test]
fn eq1_eq2_exact_on_flat() {
    // closed-form identities, exact (integer ns) on the flat fabric
    let cp = CommParams::default();
    let n = 8;
    let cluster = presets::flat(n).unwrap();
    let mut comm = Comm::with_params(&cluster, cp.clone());
    let mut engine = Engine::new(&cluster);
    for bytes in [4u64, 1 << 20] {
        let spec = BcastSpec::new(0, n, bytes);
        let direct =
            collectives::latency_ns(&Algorithm::Direct, &mut comm, &mut engine, &spec);
        let chain =
            collectives::latency_ns(&Algorithm::Chain, &mut comm, &mut engine, &spec);
        // Eq.1 vs Eq.2: identical per-hop cost, identical total on the
        // uncontended uniform fabric with n-1 transfers each
        assert_eq!(direct, chain);
    }
}
