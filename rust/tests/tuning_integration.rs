//! Integration: the enhanced tuning framework end to end — sweep, table,
//! persistence, selection, and the "tuned never loses" guarantee that
//! defines MV2-GDR-Opt.

use gdrbcast::collectives::{self, Algorithm, BcastSpec, CollectiveKind, CollectiveSpec};
use gdrbcast::comm::Comm;
use gdrbcast::netsim::Engine;
use gdrbcast::topology::presets;
use gdrbcast::tuning::{persist, space, sweep, Selector};

#[test]
fn selector_answers_per_collective_queries() {
    // the refactor's acceptance bar: one Selector serves tuned picks for
    // both the broadcast family and the reduction families
    let cluster = presets::kesch(1, 16).unwrap();
    let sel = Selector::tuned(&cluster);
    for kind in CollectiveKind::ALL {
        for bytes in [4u64, 8 << 10, 1 << 20, 64 << 20] {
            let algo = sel.algorithm_for(kind, bytes);
            assert_eq!(algo.kind(), kind, "{} pick for {kind:?}", algo.name());
        }
    }
    // structure: trees own the small end, the ring the large end
    assert!(
        matches!(
            sel.algorithm_for(CollectiveKind::Allreduce, 4),
            Algorithm::TreeAllreduce { .. }
        ),
        "small allreduce pick: {}",
        sel.algorithm_for(CollectiveKind::Allreduce, 4).name()
    );
    assert_eq!(
        sel.algorithm_for(CollectiveKind::Allreduce, 128 << 20),
        Algorithm::RingAllreduce
    );
}

#[test]
fn reduction_tables_persist_with_the_broadcast_table() {
    let cluster = presets::kesch(1, 8).unwrap();
    let sel = Selector::tuned(&cluster);
    let dir = std::env::temp_dir().join("gdrbcast-tuning-reductions");
    let path = dir.join("table.json");
    persist::save(sel.table(), &path).unwrap();
    let loaded = Selector::from_table(persist::load(&path).unwrap());
    for kind in CollectiveKind::ALL {
        for bytes in [4u64, 512 << 10, 64 << 20] {
            assert_eq!(
                sel.algorithm_for(kind, bytes),
                loaded.algorithm_for(kind, bytes),
                "selection diverged for {kind:?} at {bytes}B after persistence"
            );
        }
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn tuned_allreduce_beats_both_fixed_designs_across_the_grid() {
    let cluster = presets::kesch(1, 8).unwrap();
    let sel = Selector::tuned(&cluster);
    let mut comm = Comm::new(&cluster);
    let mut engine = Engine::new(&cluster);
    for bytes in sweep::default_sizes() {
        let spec = CollectiveSpec::allreduce(8, bytes);
        let tuned = sel.latency_ns(&mut comm, &mut engine, &spec);
        for algo in space::candidates_for(CollectiveKind::Allreduce, bytes) {
            let fixed = collectives::latency_ns(&algo, &mut comm, &mut engine, &spec);
            assert!(
                tuned <= fixed,
                "at {bytes}B tuned allreduce ({}) {tuned} lost to {} {fixed}",
                sel.algorithm_for(CollectiveKind::Allreduce, bytes).name(),
                algo.name()
            );
        }
    }
}

#[test]
fn tuned_beats_every_fixed_algorithm_on_the_grid() {
    // the defining property of the tuned runtime: at every swept size it
    // matches the best fixed candidate
    let cluster = presets::kesch(1, 16).unwrap();
    let sel = Selector::tuned(&cluster);
    let mut comm = Comm::new(&cluster);
    let mut engine = Engine::new(&cluster);
    for bytes in sweep::default_sizes() {
        let spec = BcastSpec::new(0, 16, bytes);
        let tuned = sel.latency_ns(&mut comm, &mut engine, &spec);
        for algo in space::candidates(bytes) {
            let fixed = collectives::latency_ns(&algo, &mut comm, &mut engine, &spec);
            assert!(
                tuned <= fixed,
                "at {bytes}B tuned ({}) {tuned} lost to {} {fixed}",
                sel.algorithm(bytes).name(),
                algo.name()
            );
        }
    }
}

#[test]
fn table_structure_small_to_large() {
    // §IV: staged/tree designs own the small end, pipelined designs the
    // large end
    let cluster = presets::kesch(2, 16).unwrap();
    let sel = Selector::tuned(&cluster);
    let small = sel.algorithm(4);
    assert!(
        matches!(
            small,
            Algorithm::HostStagedKnomial { .. } | Algorithm::Knomial { .. }
        ),
        "small pick: {}",
        small.name()
    );
    let large = sel.algorithm(128 << 20);
    assert!(
        matches!(
            large,
            Algorithm::PipelinedChain { .. } | Algorithm::ScatterRingAllgather
        ),
        "large pick: {}",
        large.name()
    );
}

#[test]
fn persistence_roundtrip_preserves_selection() {
    let cluster = presets::kesch(1, 8).unwrap();
    let sel = Selector::tuned(&cluster);
    let dir = std::env::temp_dir().join("gdrbcast-tuning-it");
    let path = dir.join("table.json");
    persist::save(sel.table(), &path).unwrap();
    let loaded = Selector::from_table(persist::load(&path).unwrap());
    for bytes in [4u64, 8 << 10, 512 << 10, 8 << 20, 128 << 20] {
        assert_eq!(
            sel.algorithm(bytes),
            loaded.algorithm(bytes),
            "selection diverged at {bytes}B after persistence"
        );
    }
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn parallel_tune_persists_byte_identical_table() {
    // the parallel sweep fans (kind, size) points across threads but
    // merges in grid order; the persisted artifact must be byte-for-byte
    // the serial reference's
    let cluster = presets::kesch(2, 4).unwrap();
    let sizes = [4u64, 8 << 10, 1 << 20, 16 << 20, 128 << 20];
    let par = sweep::tune(&cluster, &sizes);
    let ser = sweep::tune_serial(&cluster, &sizes);
    let dir = std::env::temp_dir().join("gdrbcast-tuning-determinism");
    let par_path = dir.join("parallel.json");
    let ser_path = dir.join("serial.json");
    persist::save(&par, &par_path).unwrap();
    persist::save(&ser, &ser_path).unwrap();
    let par_bytes = std::fs::read(&par_path).unwrap();
    let ser_bytes = std::fs::read(&ser_path).unwrap();
    assert_eq!(
        par_bytes, ser_bytes,
        "parallel tune persisted a different table than the serial reference"
    );
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn tables_differ_across_topologies() {
    // the whole point of a tuning *framework*: different machines tune
    // differently
    let kesch = Selector::tuned(&presets::kesch(1, 16).unwrap());
    let dgx = Selector::tuned(&presets::dgx1(1, 8, true).unwrap());
    let mut any_diff = false;
    for bytes in sweep::default_sizes() {
        if kesch.algorithm(bytes).family() != dgx.algorithm(bytes).family() {
            any_diff = true;
            break;
        }
    }
    // (not guaranteed in principle, but with NVLink vs PLX fabrics the
    // crossovers genuinely move; if this ever fails the presets are
    // suspiciously identical)
    assert!(any_diff, "KESCH and DGX-1V tuned identically?!");
}

#[test]
fn dgx1v_nvlink_improves_large_broadcasts() {
    // NVLink2 (22 GB/s bricks) must beat the PCIe-only KESCH node for
    // bandwidth-bound broadcasts at equal GPU count
    let kesch = presets::kesch(1, 8).unwrap();
    let dgx = presets::dgx1(1, 8, true).unwrap();
    let sk = Selector::tuned(&kesch);
    let sd = Selector::tuned(&dgx);
    let mut ck = Comm::new(&kesch);
    let mut cd = Comm::new(&dgx);
    let mut ek = Engine::new(&kesch);
    let mut ed = Engine::new(&dgx);
    let spec = BcastSpec::new(0, 8, 64 << 20);
    let tk = sk.latency_ns(&mut ck, &mut ek, &spec);
    let td = sd.latency_ns(&mut cd, &mut ed, &spec);
    assert!(td < tk, "DGX-1V {td} should beat KESCH {tk} at 64M");
}
