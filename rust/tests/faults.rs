//! Fault-injection integration tests: empty-schedule golden parity
//! across the algorithm × size × topology grid under both contention
//! models, fair-share link conservation through a mid-flight link kill
//! with detour re-routing, lone-surviving-flow parity between the
//! models, bounded-retry degraded outcomes, and Monte Carlo
//! determinism across thread counts and re-runs.

use gdrbcast::collectives::{self, Algorithm, CollectiveSpec};
use gdrbcast::comm::Comm;
use gdrbcast::netsim::{
    Deps, Engine, FaultProfile, FaultSchedule, LinkModel, Plan, SimOp, UNREACHABLE_NS,
};
use gdrbcast::topology::{presets, LinkKind};
use gdrbcast::tuning::montecarlo::{self, McConfig};

fn grid_algorithms() -> Vec<Algorithm> {
    vec![
        Algorithm::Direct,
        Algorithm::Chain,
        Algorithm::PipelinedChain { chunk: 64 << 10 },
        Algorithm::Knomial { k: 2 },
        Algorithm::Knomial { k: 4 },
        Algorithm::ScatterRingAllgather,
        Algorithm::HostStagedKnomial { k: 2 },
        Algorithm::RingReduceScatter,
        Algorithm::RingAllgather,
        Algorithm::RingAllreduce,
        Algorithm::TreeAllreduce { k: 2 },
    ]
}

fn grid_topologies() -> Vec<(&'static str, gdrbcast::topology::Cluster)> {
    vec![
        ("flat(8)", presets::flat(8).unwrap()),
        ("kesch(1,8)", presets::kesch(1, 8).unwrap()),
        ("kesch(2,8)", presets::kesch(2, 8).unwrap()),
    ]
}

#[test]
fn empty_schedule_golden_parity_grid() {
    // the acceptance gate: an installed-but-empty FaultSchedule must be
    // bit-identical to no schedule at all — per-op starts, completions
    // and makespans — for every algorithm × size × topology, under both
    // contention models
    for model in LinkModel::ALL {
        for (name, cluster) in &grid_topologies() {
            let n = cluster.n_gpus();
            let mut comm = Comm::new(cluster);
            let mut healthy = Engine::with_model(cluster, model);
            let mut gated = Engine::with_model(cluster, model);
            gated.set_faults(Some(FaultSchedule::default()));
            for algo in &grid_algorithms() {
                for bytes in [4u64, 64 << 10, 16 << 20] {
                    let spec = CollectiveSpec::collective(algo.kind(), 0, n, bytes);
                    let bp = collectives::plan(algo, &mut comm, &spec);
                    let a = healthy.execute(&bp.plan);
                    let b = gated.execute(&bp.plan);
                    let ctx = format!("{} {name} {} {bytes}B", model.name(), algo.name());
                    assert_eq!(a.makespan, b.makespan, "{ctx}: makespan diverged");
                    assert_eq!(a.start, b.start, "{ctx}: starts diverged");
                    assert_eq!(a.done, b.done, "{ctx}: completions diverged");
                    // and a healthy run reports a complete outcome
                    let outcome = b.degraded_outcome(&bp.plan, n);
                    assert!(outcome.is_complete(), "{ctx}: healthy run lost ranks");
                    assert_eq!(outcome.delivered_makespan, outcome.makespan, "{ctx}");
                }
            }
        }
    }
}

#[test]
fn clearing_faults_restores_healthy_execution() {
    // a run under a real (destructive) schedule must not leak state into
    // the next run: clearing the schedule restores bit-identical healthy
    // results (the bw-scale / event-list reset path)
    let cluster = presets::kesch(1, 8).unwrap();
    let n = cluster.n_gpus();
    let profile =
        FaultProfile::parse("kill=2@100us,degrade=2:0.3@50us,straggle=2:4,jitter=0.2").unwrap();
    let schedule = profile.realize(&cluster, 0xdead_beef).unwrap();
    for model in LinkModel::ALL {
        let mut comm = Comm::new(&cluster);
        let mut reference = Engine::with_model(&cluster, model);
        let mut reused = Engine::with_model(&cluster, model);
        for algo in [Algorithm::Chain, Algorithm::Knomial { k: 2 }] {
            let spec = CollectiveSpec::collective(algo.kind(), 0, n, 4 << 20);
            let bp = collectives::plan(&algo, &mut comm, &spec);
            let clean = reference.execute(&bp.plan);
            reused.set_faults(Some(schedule.clone()));
            let faulted = reused.execute(&bp.plan);
            reused.set_faults(None);
            let after = reused.execute(&bp.plan);
            let ctx = format!("{} {}", model.name(), algo.name());
            assert_ne!(
                clean.makespan, faulted.makespan,
                "{ctx}: destructive schedule changed nothing"
            );
            assert_eq!(clean.done, after.done, "{ctx}: fault state leaked");
            assert_eq!(clean.makespan, after.makespan, "{ctx}: fault state leaked");
        }
    }
}

/// The concurrent-transfer plan the conservation test executes on
/// kesch(2,16): op 0 is the long cross-node transfer whose IB rail the
/// schedule kills mid-flight; the rest contend on node 1's PCIe tree.
/// Zero overhead/issue so completions decompose exactly into
/// drain-instant + route latency.
fn conservation_plan(
    cluster: &gdrbcast::topology::Cluster,
) -> (Plan, Vec<gdrbcast::topology::RouteId>) {
    let mut plan = Plan::new();
    let mut routes = Vec::new();
    let pairs: [(usize, usize, u64); 5] = [
        (0, 16, 64 << 20), // the victim: node 0 -> node 1 over the s0 rail
        (16, 17, 16 << 20),
        (16, 20, 16 << 20), // shares gpu16's uplink with the one above
        (18, 21, 16 << 20), // shares plx->root->plx with 16->20
        (1, 2, 16 << 20),
    ];
    for (i, &(src, dst, bytes)) in pairs.iter().enumerate() {
        let route = cluster
            .route(cluster.rank_device(src), cluster.rank_device(dst))
            .unwrap();
        routes.push(route);
        plan.push(
            SimOp::Transfer {
                route,
                bytes,
                overhead_ns: 0,
                issue_ns: 0,
                bw_cap: None,
            },
            Deps::none(),
            Some((dst, i)),
        );
    }
    (plan, routes)
}

#[test]
fn fairshare_conserves_capacity_through_midflight_kill_and_reroute() {
    // kill the victim's FDR uplink mid-flight. The fair-share loop must
    // (a) drop the in-flight flow off the dead link and re-admit it on a
    // detour after the retry timeout, (b) keep every link's allocated
    // rate sum within its (possibly zeroed) capacity at every event
    // instant, and (c) still deliver every rank
    let cluster = presets::kesch(2, 16).unwrap();
    let (plan, plan_routes) = conservation_plan(&cluster);
    let kill_ns: u64 = 2_000_000; // 2 ms — the 64 MB FDR flow needs ~9 ms
    let victim_route = cluster
        .route(cluster.rank_device(0), cluster.rank_device(16))
        .unwrap();
    let victim = cluster.route_view(victim_route);
    let dead_link = *victim
        .hops
        .iter()
        .find(|&&h| cluster.link(h).kind == LinkKind::IbFdr)
        .expect("cross-node route crosses the FDR rail");
    let schedule = FaultSchedule::default().with_link_event(kill_ns, dead_link, 0.0);
    let timeout_ns = schedule.retry_timeout_ns;

    let mut engine = Engine::with_model(&cluster, LinkModel::FairShare);
    engine.set_faults(Some(schedule));
    let (result, events) = engine.execute_with_flow_trace(&plan);

    // (c) delivered everywhere, with the victim finishing after the kill
    let outcome = result.degraded_outcome(&plan, cluster.n_gpus());
    assert!(
        outcome.is_complete(),
        "detour must deliver: lost ranks {:?}",
        outcome.undelivered
    );
    assert!(result.makespan < UNREACHABLE_NS);
    assert!(
        result.done[0] > kill_ns,
        "victim was not in flight at the kill instant"
    );

    // (a) reconstruct the detour the engine re-admitted the victim on:
    // the first attempt happens one retry timeout after the kill applies
    let t_re = kill_ns + timeout_ns;
    let meta = cluster.route_meta(victim_route);
    let detour_id = engine
        .detour_route(meta.src, meta.dst, t_re)
        .expect("a socket-1 detour must survive a single-rail kill");
    let detour = cluster.route_view(detour_id);
    assert!(
        !detour.hops.contains(&dead_link),
        "detour still crosses the killed link"
    );
    assert!(
        result.done[0] >= t_re + detour.latency_ns,
        "victim cannot finish before its re-admission plus the detour latency"
    );

    // (b) per-link conservation at every event instant. Final route and
    // drain instant per op (zero overheads: done = drain + latency):
    let n_ops = plan.len();
    let mut final_route = Vec::with_capacity(n_ops);
    let mut drain = Vec::with_capacity(n_ops);
    for op in 0..n_ops {
        let r = if op == 0 { detour_id } else { plan_routes[op] };
        let lat = cluster.route_view(r).latency_ns;
        final_route.push(r);
        drain.push(result.done[op].saturating_sub(lat));
    }
    let mut instants: Vec<u64> = events.iter().map(|e| e.t_ns).collect();
    instants.dedup();
    let mut cur_rate = vec![0.0f64; n_ops];
    let mut cursor = 0usize;
    for &t in &instants {
        while cursor < events.len() && events[cursor].t_ns <= t {
            cur_rate[events[cursor].op] = events[cursor].rate;
            cursor += 1;
        }
        let mut per_link = vec![0.0f64; cluster.n_links()];
        for op in 0..n_ops {
            if t >= drain[op] {
                continue; // already retired
            }
            // the victim is off the fabric between the kill and its
            // re-admission, and runs the detour afterwards
            let route = if op == 0 {
                if t >= kill_ns && t < t_re {
                    continue;
                }
                if t < kill_ns {
                    plan_routes[0]
                } else {
                    detour_id
                }
            } else {
                final_route[op]
            };
            for &h in cluster.route_view(route).hops.iter() {
                per_link[h.0] += cur_rate[op];
            }
        }
        for (l, &used) in per_link.iter().enumerate() {
            let factor = if l == dead_link.0 && t >= kill_ns {
                0.0
            } else {
                1.0
            };
            let cap = cluster.links()[l].bandwidth * factor;
            assert!(
                used <= cap * (1.0 + 1e-6) + 1e-6,
                "t={t}: link {l} oversubscribed ({used} > {cap})"
            );
        }
    }
}

#[test]
fn lone_surviving_flow_matches_fifo_under_faults() {
    // two disjoint transfers; one's only uplink is killed at t = 0 with
    // a zero retry budget. Both models must agree exactly: the survivor
    // is a lone flow (max-min rate == FIFO bottleneck) and the victim
    // completes through the shared sentinel formula
    let cluster = presets::flat(4).unwrap();
    let bytes: u64 = 8 << 20;
    let mut plan = Plan::new();
    for &(src, dst) in &[(0usize, 1usize), (2, 3)] {
        let route = cluster
            .route(cluster.rank_device(src), cluster.rank_device(dst))
            .unwrap();
        plan.push(
            SimOp::Transfer {
                route,
                bytes,
                overhead_ns: 1000,
                issue_ns: 1000,
                bw_cap: None,
            },
            Deps::none(),
            Some((dst, 0)),
        );
    }
    let victim_route = cluster
        .route(cluster.rank_device(0), cluster.rank_device(1))
        .unwrap();
    let dead_link = cluster.route_view(victim_route).hops[0];
    let schedule = FaultSchedule::default()
        .with_link_event(0, dead_link, 0.0)
        .with_retry(0, 0);

    let mut results = Vec::new();
    for model in LinkModel::ALL {
        let mut engine = Engine::with_model(&cluster, model);
        engine.set_faults(Some(schedule.clone()));
        results.push((model, engine.execute(&plan)));
    }
    let (_, fifo) = &results[0];
    for (model, r) in &results[1..] {
        assert_eq!(fifo.done, r.done, "{} diverged from FIFO", model.name());
        assert_eq!(fifo.makespan, r.makespan, "{}", model.name());
    }
    // the victim hit the sentinel, the survivor did not, and the
    // degraded outcome reports exactly that split
    for (model, r) in &results {
        assert!(r.done[0] >= UNREACHABLE_NS, "{}", model.name());
        assert!(r.done[1] < UNREACHABLE_NS, "{}", model.name());
        let outcome = r.degraded_outcome(&plan, cluster.n_gpus());
        assert_eq!(outcome.undelivered, vec![1], "{}", model.name());
        assert_eq!(outcome.delivered_ranks(), 3, "{}", model.name());
        assert_eq!(outcome.delivered_makespan, r.done[1], "{}", model.name());
        assert!(outcome.makespan >= UNREACHABLE_NS, "{}", model.name());
    }
}

#[test]
fn dead_rail_detours_or_degrades_with_budget() {
    // a cross-node transfer whose IB rail dies at t = 0: with the
    // default retry budget both models deliver over a detour (slower
    // than healthy); with a zero budget the destination rank is
    // reported undelivered instead of the run panicking
    let cluster = presets::kesch(2, 8).unwrap();
    let route = cluster
        .route(cluster.rank_device(0), cluster.rank_device(8))
        .unwrap();
    let dead_link = *cluster
        .route_view(route)
        .hops
        .iter()
        .find(|&&h| cluster.link(h).kind == LinkKind::IbFdr)
        .expect("cross-node route crosses the FDR rail");
    let mut plan = Plan::new();
    plan.push(
        SimOp::Transfer {
            route,
            bytes: 4 << 20,
            overhead_ns: 1000,
            issue_ns: 1000,
            bw_cap: None,
        },
        Deps::none(),
        Some((8, 0)),
    );
    for model in LinkModel::ALL {
        let mut healthy = Engine::with_model(&cluster, model);
        let base = healthy.execute(&plan);

        let mut engine = Engine::with_model(&cluster, model);
        engine.set_faults(Some(
            FaultSchedule::default().with_link_event(0, dead_link, 0.0),
        ));
        let detoured = engine.execute(&plan);
        let outcome = detoured.degraded_outcome(&plan, cluster.n_gpus());
        assert!(outcome.is_complete(), "{}: detour failed", model.name());
        assert!(
            detoured.makespan > base.makespan,
            "{}: detour cannot beat the direct rail",
            model.name()
        );
        assert!(detoured.makespan < UNREACHABLE_NS, "{}", model.name());

        let mut starved = Engine::with_model(&cluster, model);
        starved.set_faults(Some(
            FaultSchedule::default()
                .with_link_event(0, dead_link, 0.0)
                .with_retry(0, 0),
        ));
        let lost = starved.execute(&plan).degraded_outcome(&plan, cluster.n_gpus());
        assert_eq!(lost.undelivered, vec![8], "{}", model.name());
        assert!(lost.makespan >= UNREACHABLE_NS, "{}", model.name());
        assert!(lost.delivered_makespan < UNREACHABLE_NS, "{}", model.name());
    }
}

#[test]
fn stragglers_and_degradation_slow_both_models_deterministically() {
    // a non-destructive profile (no kills) must slow execution without
    // losing ranks, identically across engine instances
    let cluster = presets::kesch(1, 8).unwrap();
    let n = cluster.n_gpus();
    let profile = FaultProfile::parse("degrade=2:0.4@100us,straggle=1:3,jitter=0.05").unwrap();
    let schedule = profile.realize(&cluster, 17).unwrap();
    let mut comm = Comm::new(&cluster);
    let spec = CollectiveSpec::new(0, n, 8 << 20);
    let bp = collectives::plan(&Algorithm::Knomial { k: 2 }, &mut comm, &spec);
    for model in LinkModel::ALL {
        let mut healthy = Engine::with_model(&cluster, model);
        let base = healthy.execute(&bp.plan).makespan;
        let mut a = Engine::with_model(&cluster, model);
        a.set_faults(Some(schedule.clone()));
        let ra = a.execute(&bp.plan);
        let mut b = Engine::with_model(&cluster, model);
        b.set_faults(Some(schedule.clone()));
        let rb = b.execute(&bp.plan);
        assert_eq!(ra.done, rb.done, "{}: nondeterministic", model.name());
        assert!(
            ra.makespan > base,
            "{}: degradation + stragglers must cost time",
            model.name()
        );
        assert!(
            ra.degraded_outcome(&bp.plan, n).is_complete(),
            "{}: non-destructive profile lost ranks",
            model.name()
        );
    }
}

#[test]
fn montecarlo_rows_are_identical_across_runs_and_threads() {
    // the CLI-facing determinism gate: same (profile, seed, cluster) ⇒
    // byte-identical p50/p99 rows on every re-run and for every
    // --tune-threads setting, under both link models
    let cluster = presets::kesch(2, 8).unwrap();
    let algos = [Algorithm::Chain, Algorithm::Knomial { k: 2 }];
    let sizes = [64u64 << 10, 4 << 20];
    let profile = FaultProfile::parse("kill=1@500us,straggle=1:3,jitter=0.05").unwrap();
    for link_model in LinkModel::ALL {
        let cfg = McConfig {
            trials: 6,
            seed: 42,
            link_model,
            threads: Some(1),
        };
        let reference = montecarlo::run(&cluster, &algos, &sizes, &profile, &cfg).unwrap();
        assert_eq!(reference.len(), algos.len() * sizes.len());
        for r in &reference {
            assert_eq!(r.trials, 6);
            // aborted_frac partitions the trial population with the
            // delivered fraction — and must be as deterministic as the
            // latency stats below
            let frac = r.aborted_frac();
            assert!((0.0..=1.0).contains(&frac), "aborted_frac out of range");
        }
        // re-run with a freshly parsed profile: determinism must not
        // depend on object identity
        let again = FaultProfile::parse("kill=1@500us,straggle=1:3,jitter=0.05").unwrap();
        let rerun = montecarlo::run(&cluster, &algos, &sizes, &again, &cfg).unwrap();
        assert_eq!(rerun, reference, "{}: re-run diverged", link_model.name());
        for threads in [Some(2), Some(4), None] {
            let cfg_t = McConfig { threads, ..cfg };
            let rows = montecarlo::run(&cluster, &algos, &sizes, &profile, &cfg_t).unwrap();
            assert_eq!(
                rows, reference,
                "{}: threads={threads:?} diverged",
                link_model.name()
            );
        }
    }
}
