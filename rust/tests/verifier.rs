//! Integration sweep of the static plan verifier: every algorithm the
//! simulator ships, across sizes and topology families, must verify
//! clean through the public API — plus the lifecycle cases the `verify`
//! CLI exercises (merged overlap timelines, post-`kill_link` staleness).

use gdrbcast::analysis::{self, Code};
use gdrbcast::collectives::{self, Algorithm, CollectiveKind, CollectiveSpec};
use gdrbcast::comm::Comm;
use gdrbcast::netsim::Plan;
use gdrbcast::topology::presets::{flat, kesch};
use gdrbcast::topology::{Cluster, LinkKind};

fn menu() -> Vec<Algorithm> {
    vec![
        Algorithm::Direct,
        Algorithm::Chain,
        Algorithm::PipelinedChain { chunk: 64 << 10 },
        Algorithm::Knomial { k: 2 },
        Algorithm::Knomial { k: 4 },
        Algorithm::ScatterRingAllgather,
        Algorithm::HostStagedKnomial { k: 2 },
        Algorithm::RingReduceScatter,
        Algorithm::RingAllgather,
        Algorithm::RingAllreduce,
        Algorithm::TreeAllreduce { k: 2 },
    ]
}

fn spec_for(algo: &Algorithm, n: usize, bytes: u64) -> CollectiveSpec {
    match algo.kind() {
        CollectiveKind::Broadcast => CollectiveSpec::new(0, n, bytes),
        CollectiveKind::ReduceScatter => CollectiveSpec::reduce_scatter(n, bytes),
        CollectiveKind::Allgather => CollectiveSpec::allgather(n, bytes),
        CollectiveKind::Allreduce => CollectiveSpec::allreduce(n, bytes),
    }
}

fn topologies() -> Vec<(&'static str, Cluster)> {
    vec![
        ("flat(8)", flat(8).unwrap()),
        ("kesch(1,16)", kesch(1, 16).unwrap()),
        ("kesch(2,8)", kesch(2, 8).unwrap()),
    ]
}

#[test]
fn full_grid_verifies_clean() {
    for (tname, cluster) in topologies() {
        let n = cluster.n_gpus();
        let mut comm = Comm::new(&cluster);
        for algo in menu() {
            for bytes in [64u64 << 10, 1 << 20, 16 << 20] {
                let spec = spec_for(&algo, n, bytes);
                let cp = collectives::plan(&algo, &mut comm, &spec);
                let diags = analysis::verify_collective(&cluster, &cp);
                assert!(
                    !analysis::has_errors(&diags),
                    "{tname} {} {bytes}B:\n{}",
                    algo.name(),
                    analysis::render(&diags)
                );
            }
        }
    }
}

#[test]
fn merged_overlap_timeline_verifies_clean() {
    let cluster = kesch(2, 8).unwrap();
    let n = cluster.n_gpus();
    let mut comm = Comm::new(&cluster);
    let mut timeline = Plan::new();
    let ar = collectives::plan(
        &Algorithm::RingAllreduce,
        &mut comm,
        &CollectiveSpec::allreduce(n, 1 << 20),
    );
    let h = timeline.merge(&ar.plan);
    let gate = [h.offset + ar.plan.len() - 1];
    let bc = collectives::plan(
        &Algorithm::PipelinedChain { chunk: 64 << 10 },
        &mut comm,
        &CollectiveSpec::new(0, n, 1 << 20),
    );
    timeline.merge_after(&bc.plan, &gate);
    let diags = analysis::verify_plan(&cluster, &timeline);
    assert!(
        !analysis::has_errors(&diags),
        "{}",
        analysis::render(&diags)
    );
}

#[test]
fn post_kill_stale_plan_flagged_and_replan_clean() {
    let mut cluster = kesch(2, 8).unwrap();
    let n = cluster.n_gpus();
    let spec = CollectiveSpec::new(0, n, 1 << 20);
    let stale = {
        let mut comm = Comm::new(&cluster);
        collectives::plan(&Algorithm::Chain, &mut comm, &spec)
    };
    // kill one FDR rail of the dual-rail node: the graph stays routable
    // through the sibling socket, but every pre-kill route goes stale
    let cross = cluster
        .route(cluster.rank_device(7), cluster.rank_device(8))
        .unwrap();
    let rail = *cluster
        .route_view(cross)
        .hops
        .iter()
        .find(|&&h| cluster.link(h).kind == LinkKind::IbFdr)
        .expect("cross-node route crosses an FDR rail");
    cluster.kill_link(rail).unwrap();

    let diags = analysis::verify_collective(&cluster, &stale);
    assert!(
        diags.iter().any(|d| d.code == Code::StaleRoute),
        "stale plan not flagged PL005:\n{}",
        analysis::render(&diags)
    );

    let rebuilt = {
        let mut comm = Comm::new(&cluster);
        collectives::plan(&Algorithm::Chain, &mut comm, &spec)
    };
    let diags = analysis::verify_collective(&cluster, &rebuilt);
    assert!(
        !analysis::has_errors(&diags),
        "replan on the surviving topology must verify clean:\n{}",
        analysis::render(&diags)
    );
}

#[test]
fn label_mutation_caught_through_public_api() {
    // the one mutation expressible without crate-private column access:
    // hijack a delivery label and expect PL009 (duplicate) + PL010
    // (the hijacked slot goes undelivered)
    let cluster = flat(8).unwrap();
    let mut comm = Comm::new(&cluster);
    let mut cp = collectives::plan(
        &Algorithm::Chain,
        &mut comm,
        &CollectiveSpec::new(0, 8, 1 << 20),
    );
    let labeled: Vec<usize> = (0..cp.plan.len())
        .filter(|&i| cp.plan.label_of(i).is_some())
        .collect();
    let hijack = cp.plan.label_of(labeled[0]);
    cp.plan.set_label(labeled[1], hijack);
    let diags = analysis::verify_collective(&cluster, &cp);
    let codes: Vec<Code> = diags.iter().map(|d| d.code).collect();
    assert!(codes.contains(&Code::DuplicateLabel), "{codes:?}");
    assert!(codes.contains(&Code::MissingDelivery), "{codes:?}");
}

#[test]
fn diagnostics_render_deterministically() {
    let cluster = flat(8).unwrap();
    let mut comm = Comm::new(&cluster);
    let mut cp = collectives::plan(
        &Algorithm::Chain,
        &mut comm,
        &CollectiveSpec::new(0, 8, 1 << 20),
    );
    let labeled: Vec<usize> = (0..cp.plan.len())
        .filter(|&i| cp.plan.label_of(i).is_some())
        .collect();
    let hijack = cp.plan.label_of(labeled[0]);
    cp.plan.set_label(labeled[1], hijack);
    let a = analysis::render(&analysis::verify_collective(&cluster, &cp));
    let b = analysis::render(&analysis::verify_collective(&cluster, &cp));
    assert_eq!(a, b, "report must be byte-identical run to run");
    assert!(a.contains("PL009"), "{a}");
}
