//! Recovery-layer integration tests: golden parity (with no faults every
//! recovery policy's multi-iteration job is bit-identical to the
//! no-recovery path, for both the repeated-collective and training
//! workloads, under both link models), the mid-job link-kill acceptance
//! scenario (replan finishes every iteration and the rebuilt ring avoids
//! the dead rail, reconstructed from the flow trace), and the
//! exhausted-detour contract (a victim with no live via completes at the
//! sentinel at the same instant whatever the retry budget).

use gdrbcast::collectives::{self, Algorithm, CollectiveSpec};
use gdrbcast::comm::Comm;
use gdrbcast::coordinator::{
    run_collective_job, run_training_job, ExchangeOptions, RecoveryConfig, RecoveryPolicy,
    TrainingMode,
};
use gdrbcast::models;
use gdrbcast::netsim::{Deps, Engine, FaultSchedule, LinkModel, Plan, SimOp, UNREACHABLE_NS};
use gdrbcast::topology::{presets, LinkKind};
use gdrbcast::tuning::Selector;

fn all_policies() -> [RecoveryPolicy; 4] {
    [
        RecoveryPolicy::None,
        RecoveryPolicy::Replan,
        RecoveryPolicy::Shrink,
        RecoveryPolicy::Restart {
            restore_ns: 1 << 20,
        },
    ]
}

#[test]
fn healthy_collective_job_is_policy_invariant_under_both_models() {
    // the golden-parity acceptance gate, collective flavour: with no
    // faults, an N-iteration job under ANY recovery policy is
    // bit-identical to N× the single-iteration simulation — the policy
    // machinery must cost nothing when nothing fails
    let cluster = presets::kesch(2, 8).unwrap();
    let n = cluster.n_gpus();
    let bytes: u64 = 1 << 20;
    let algo = Algorithm::Chain;
    let empty = FaultSchedule::default();
    for model in LinkModel::ALL {
        let mut comm = Comm::new(&cluster);
        let mut engine = Engine::with_model(&cluster, model);
        let spec = CollectiveSpec::new(0, n, bytes);
        let one = collectives::latency_ns(&algo, &mut comm, &mut engine, &spec);
        let reference = run_collective_job(
            &cluster,
            &algo,
            bytes,
            4,
            &empty,
            model,
            &RecoveryConfig::default(),
        );
        assert!(!reference.aborted);
        assert_eq!(reference.total_ns, 4 * one, "{}", model.name());
        for policy in all_policies() {
            let job = run_collective_job(
                &cluster,
                &algo,
                bytes,
                4,
                &empty,
                model,
                &RecoveryConfig::with_policy(policy),
            );
            let ctx = format!("{} {}", model.name(), policy.name());
            assert_eq!(job, reference, "{ctx}: healthy outcome diverged");
            assert_eq!(job.recoveries, 0, "{ctx}");
            assert_eq!(job.completed, 4, "{ctx}");
            assert_eq!(job.last_iteration_ns, one, "{ctx}");
            assert_eq!(job.final_n_ranks(), n, "{ctx}");
        }
    }
}

#[test]
fn healthy_training_job_is_policy_invariant_under_both_models() {
    // same gate, training flavour: compute + full exchange per
    // iteration, barrier and overlap composition both pinned
    let cluster = presets::kesch(1, 4).unwrap();
    let model_net = models::alexnet();
    for link_model in LinkModel::ALL {
        let sel = Selector::tuned_with_model(&cluster, Some(1), link_model);
        for overlap in [false, true] {
            let opts = ExchangeOptions {
                overlap,
                link_model,
                ..ExchangeOptions::default()
            };
            let single = run_training_job(
                &cluster,
                &model_net,
                &sel,
                TrainingMode::AllreduceGradients,
                1,
                256,
                0.0,
                opts,
            );
            assert!(!single.aborted);
            assert!(single.total_ns > 0);
            for policy in all_policies() {
                let jopts = ExchangeOptions {
                    recovery: RecoveryConfig::with_policy(policy),
                    ..opts
                };
                let job = run_training_job(
                    &cluster,
                    &model_net,
                    &sel,
                    TrainingMode::AllreduceGradients,
                    3,
                    256,
                    0.0,
                    jopts,
                );
                let ctx = format!(
                    "{} overlap={overlap} {}",
                    link_model.name(),
                    policy.name()
                );
                assert!(!job.aborted, "{ctx}");
                assert_eq!(job.completed, 3, "{ctx}");
                assert_eq!(job.recoveries, 0, "{ctx}");
                assert_eq!(
                    job.total_ns,
                    3 * single.total_ns,
                    "{ctx}: policy cost leaked into a healthy job"
                );
                assert_eq!(job.last_iteration_ns, single.total_ns, "{ctx}");
            }
        }
    }
}

#[test]
fn installed_empty_schedule_matches_absent_faults_in_job_mode() {
    // an ExchangeOptions with `faults: Some(&empty)` must drive the job
    // identically to `faults: None` — the engine golden-parity contract
    // lifted to the multi-iteration runner
    let cluster = presets::kesch(1, 4).unwrap();
    let model_net = models::alexnet();
    let sel = Selector::tuned_with_threads(&cluster, Some(1));
    let empty = FaultSchedule::default();
    let base = ExchangeOptions {
        recovery: RecoveryConfig::with_policy(RecoveryPolicy::Replan),
        ..ExchangeOptions::default()
    };
    let without = run_training_job(
        &cluster,
        &model_net,
        &sel,
        TrainingMode::PartitionedBcast,
        3,
        256,
        0.0,
        base,
    );
    let with_empty = run_training_job(
        &cluster,
        &model_net,
        &sel,
        TrainingMode::PartitionedBcast,
        3,
        256,
        0.0,
        ExchangeOptions {
            faults: Some(&empty),
            ..base
        },
    );
    assert_eq!(without, with_empty);
}

#[test]
fn replan_survives_midjob_rail_kill_and_rebuilt_ring_avoids_dead_links() {
    // the PR's acceptance scenario: a chain broadcast job on kesch(2,8)
    // loses the FDR rail its cross-node hop runs on, mid-job, with a
    // zero retry budget (no engine-level detour). The replan policy must
    // observe the failure, remove the rail from the routable graph,
    // rebuild the ring on the surviving topology and finish every
    // iteration with the full world intact — verified by replaying the
    // rebuilt plan with a flow trace and checking no flow touches a
    // dead link.
    let cluster = presets::kesch(2, 8).unwrap();
    let n = cluster.n_gpus();
    let bytes: u64 = 1 << 20;
    let algo = Algorithm::Chain;

    // one healthy iteration, to place the kill mid-iteration-2
    let one = {
        let mut comm = Comm::new(&cluster);
        let mut engine = Engine::with_model(&cluster, LinkModel::FairShare);
        let spec = CollectiveSpec::new(0, n, bytes);
        collectives::latency_ns(&algo, &mut comm, &mut engine, &spec)
    };
    assert!(one > 0 && one < UNREACHABLE_NS);

    // the chain's cross-node hop is rank 7 -> rank 8; kill its FDR rail
    let cross = cluster
        .route(cluster.rank_device(7), cluster.rank_device(8))
        .unwrap();
    let dead_link = *cluster
        .route_view(cross)
        .hops
        .iter()
        .find(|&&h| cluster.link(h).kind == LinkKind::IbFdr)
        .expect("cross-node route crosses an FDR rail");
    let sched = FaultSchedule::default()
        .with_link_event(one + one / 2, dead_link, 0.0)
        .with_retry(0, 1000);

    let rc = RecoveryConfig::with_policy(RecoveryPolicy::Replan);
    let job = run_collective_job(
        &cluster,
        &algo,
        bytes,
        4,
        &sched,
        LinkModel::FairShare,
        &rc,
    );
    assert!(!job.aborted, "{job:?}");
    assert_eq!(job.completed, 4, "replan must finish every iteration");
    assert_eq!(job.recoveries, 1, "{job:?}");
    // the other socket's rail keeps every rank reachable: full world
    assert_eq!(job.alive_ranks, (0..n).collect::<Vec<_>>(), "{job:?}");
    assert!(
        job.last_iteration_ns < UNREACHABLE_NS,
        "final iteration still hit the sentinel: {job:?}"
    );
    assert!(
        job.dead_links.contains(&dead_link),
        "the killed rail was never observed: {job:?}"
    );
    // time accounting: detection + replan charges on top of the work
    assert!(job.total_ns > 4 * one);

    // reconstruct the surviving topology the job re-planned on and
    // replay the rebuilt ring with a flow trace
    let mut survivor = cluster.clone();
    for &l in &job.dead_links {
        survivor.kill_link(l).unwrap();
    }
    let mut comm = Comm::new(&survivor);
    let spec = CollectiveSpec::new(0, n, bytes);
    let bp = collectives::plan(&algo, &mut comm, &spec);
    let mut engine = Engine::with_model(&survivor, LinkModel::FairShare);
    let (result, events) = engine.execute_with_flow_trace(&bp.plan);
    let outcome = result.degraded_outcome(&bp.plan, n);
    assert!(
        outcome.is_complete(),
        "rebuilt ring lost ranks: {:?}",
        outcome.undelivered
    );
    assert!(result.makespan < UNREACHABLE_NS);
    assert!(!events.is_empty(), "flow trace is empty");
    for ev in &events {
        if let SimOp::Transfer { route, .. } = bp.plan.op(ev.op) {
            let hops = survivor.route_view(route).hops;
            for d in &job.dead_links {
                assert!(
                    !hops.contains(d),
                    "rebuilt ring still crosses dead link {d:?} (op {})",
                    ev.op
                );
            }
        }
    }
    // and the re-formed ring genuinely re-routed: the original topology
    // ran the cross-node hop over the now-dead rail
    assert!(cluster.route_view(cross).hops.contains(&dead_link));
    let rerouted = survivor
        .route(survivor.rank_device(7), survivor.rank_device(8))
        .unwrap();
    assert!(!survivor.route_view(rerouted).hops.contains(&dead_link));
}

#[test]
fn exhausted_detour_candidates_hit_sentinel_without_burning_budget() {
    // kill every link touching rank 3's GPU: no Host/IbHca via can reach
    // it, so detour_route must report None and the victim completes at
    // the unreachable sentinel — at the *same instant* whatever the
    // retry budget (the engine must not charge timeouts looping over a
    // detour set with no survivors)
    let cluster = presets::kesch(1, 4).unwrap();
    let victim_dev = cluster.rank_device(3);
    let mut base = FaultSchedule::default();
    for l in cluster.links() {
        if l.src == victim_dev || l.dst == victim_dev {
            base = base.with_link_event(0, l.id, 0.0);
        }
    }
    let route = cluster
        .route(cluster.rank_device(0), victim_dev)
        .unwrap();
    let mut plan = Plan::new();
    plan.push(
        SimOp::Transfer {
            route,
            bytes: 1 << 20,
            overhead_ns: 1000,
            issue_ns: 1000,
            bw_cap: None,
        },
        Deps::none(),
        Some((3, 0)),
    );
    for model in LinkModel::ALL {
        let mut results = Vec::new();
        for budget in [0u32, 4] {
            let mut engine = Engine::with_model(&cluster, model);
            engine.set_faults(Some(base.clone().with_retry(budget, 10_000)));
            let r = engine.execute(&plan);
            assert!(
                r.done[0] >= UNREACHABLE_NS,
                "{} budget={budget}: victim delivered without a live route",
                model.name()
            );
            let outcome = r.degraded_outcome(&plan, cluster.n_gpus());
            assert_eq!(outcome.undelivered, vec![3], "{}", model.name());
            // every via candidate is dead at any retry instant
            assert!(
                engine
                    .detour_route(cluster.rank_device(0), victim_dev, 20_000)
                    .is_none(),
                "{} budget={budget}: a detour survived the isolation",
                model.name()
            );
            results.push(r);
        }
        assert_eq!(
            results[0].done, results[1].done,
            "{}: retry budget changed the give-up instant on a dead detour set",
            model.name()
        );
        assert_eq!(results[0].makespan, results[1].makespan, "{}", model.name());
    }
}

#[test]
fn shrink_job_rescales_and_restart_heals_on_the_integration_preset() {
    // end-to-end policy comparison on kesch(2,8): isolate rank 15's GPU
    // at t = 0 (undetourable), run the same job under shrink and
    // restart. Shrink continues at n-1; restart heals (the t = 0 kill is
    // in the past after the restore) and keeps the full world.
    let cluster = presets::kesch(2, 8).unwrap();
    let n = cluster.n_gpus();
    let victim_dev = cluster.rank_device(n - 1);
    let mut sched = FaultSchedule::default().with_retry(0, 1000);
    for l in cluster.links() {
        if l.src == victim_dev || l.dst == victim_dev {
            sched = sched.with_link_event(0, l.id, 0.0);
        }
    }
    let shrink = run_collective_job(
        &cluster,
        &Algorithm::Chain,
        1 << 20,
        3,
        &sched,
        LinkModel::Fifo,
        &RecoveryConfig::with_policy(RecoveryPolicy::Shrink),
    );
    assert!(!shrink.aborted, "{shrink:?}");
    assert_eq!(shrink.completed, 3);
    assert_eq!(
        shrink.alive_ranks,
        (0..n - 1).collect::<Vec<_>>(),
        "shrink drops exactly the cut-off rank"
    );
    let restart = run_collective_job(
        &cluster,
        &Algorithm::Chain,
        1 << 20,
        3,
        &sched,
        LinkModel::Fifo,
        &RecoveryConfig::with_policy(RecoveryPolicy::Restart {
            restore_ns: 1 << 20,
        }),
    );
    assert!(!restart.aborted, "{restart:?}");
    assert_eq!(restart.completed, 3);
    assert_eq!(restart.final_n_ranks(), n, "restart keeps the full world");
    assert!(restart.dead_links.is_empty(), "restart heals observed damage");
}
