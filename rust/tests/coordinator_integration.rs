//! Integration: the data-parallel coordinator — threaded leader/worker
//! protocol, simulated comm accounting, and the Fig. 3 estimator's
//! qualitative claims.

use gdrbcast::coordinator::train::estimate_iteration;
use gdrbcast::coordinator::worker::QuadBackend;
use gdrbcast::coordinator::{
    comm_time_ns, run_threaded, BcastBackend, SgdConfig,
};
use gdrbcast::models::{bcast_messages, zoo, MessageSchedule};
use gdrbcast::nccl::NcclParams;
use gdrbcast::netsim::Engine;
use gdrbcast::comm::Comm;
use gdrbcast::topology::presets;
use gdrbcast::tuning::Selector;

#[test]
fn threaded_training_with_simulated_comm() {
    // 8 worker threads against the leader, with per-iteration comm cost
    // coming from the simulator — the full L3 composition minus PJRT
    let cluster = presets::kesch(1, 8).unwrap();
    let sel = Selector::tuned(&cluster);
    let model = zoo::vgg_mini();
    let msgs = bcast_messages(&model, 8, MessageSchedule::Partitioned);
    let mut comm = Comm::new(&cluster);
    let mut engine = Engine::new(&cluster);
    let comm_ns = comm_time_ns(&mut comm, &mut engine, &BcastBackend::Mv2Opt(&sel), &msgs);
    assert!(comm_ns > 0);

    let target: Vec<f32> = (0..64).map(|i| ((i * 37) % 19) as f32 / 10.0).collect();
    let workers: Vec<QuadBackend> = (0..8).map(|_| QuadBackend::new(target.clone())).collect();
    let mut params = vec![0.0f32; target.len()];
    let metrics = run_threaded(
        &mut params,
        workers,
        &SgdConfig {
            lr: 0.2,
            iterations: 50,
        },
        |_| comm_ns,
    );
    assert!(metrics.loss_decreased());
    assert!(metrics.final_loss() < 1e-4);
    assert_eq!(metrics.total_comm_ns(), comm_ns * 50);
}

#[test]
fn mv2_opt_never_slower_than_nccl_mv2_for_vgg() {
    // the Fig. 3 "matches or beats at every scale" claim
    let nccl = NcclParams::default();
    let model = zoo::vgg16();
    for (nodes, gpn) in [(1usize, 8usize), (2, 16)] {
        let cluster = presets::kesch(nodes, gpn).unwrap();
        let sel = Selector::tuned(&cluster);
        let batch = 16 * cluster.n_gpus();
        let a = estimate_iteration(&cluster, &model, &BcastBackend::Mv2Opt(&sel), batch, 0.0);
        let b = estimate_iteration(&cluster, &model, &BcastBackend::NcclMv2(&nccl), batch, 0.0);
        assert!(
            a.iter_us <= b.iter_us * 1.001,
            "{} GPUs: MV2 {} vs NCCL {}",
            cluster.n_gpus(),
            a.iter_us,
            b.iter_us
        );
    }
}

#[test]
fn comm_shrinks_relative_to_compute_with_fewer_ranks() {
    // partitioned messages grow as ranks shrink, but total comm volume is
    // constant; compute per GPU grows with weak scaling — sanity-check
    // the estimator's proportions
    let model = zoo::vgg16();
    let cluster = presets::kesch(1, 8).unwrap();
    let sel = Selector::tuned(&cluster);
    let est = estimate_iteration(&cluster, &model, &BcastBackend::Mv2Opt(&sel), 128, 0.0);
    assert!(est.compute_us > 0.0);
    assert!(est.comm_us > 0.0);
    assert!(est.throughput > 0.0);
    // VGG at 8 GPUs: compute must dominate (the paper's premise that the
    // 7% win comes from the comm slice)
    assert!(est.compute_us > est.comm_us);
}

#[test]
fn googlenet_benefits_at_scale() {
    // §V-D expectation: smaller models (GoogLeNet) shift toward the
    // small/medium message band where the proposed designs win
    let nccl = NcclParams::default();
    let model = zoo::googlenet();
    let cluster = presets::kesch(4, 16).unwrap();
    let sel = Selector::tuned(&cluster);
    let batch = 16 * cluster.n_gpus();
    let a = estimate_iteration(&cluster, &model, &BcastBackend::Mv2Opt(&sel), batch, 0.0);
    let b = estimate_iteration(&cluster, &model, &BcastBackend::NcclMv2(&nccl), batch, 0.0);
    assert!(a.comm_us < b.comm_us, "mv2 {} nccl {}", a.comm_us, b.comm_us);
}

#[test]
fn per_layer_schedule_also_supported() {
    let cluster = presets::kesch(1, 4).unwrap();
    let sel = Selector::tuned(&cluster);
    let model = zoo::lenet5();
    let msgs = bcast_messages(&model, 4, MessageSchedule::PerLayer);
    assert_eq!(msgs.len(), model.layers.len());
    let mut comm = Comm::new(&cluster);
    let mut engine = Engine::new(&cluster);
    let t = comm_time_ns(&mut comm, &mut engine, &BcastBackend::Mv2Opt(&sel), &msgs);
    assert!(t > 0);
}
