//! Property tests: collective invariants over random topologies, roots,
//! sizes, chunk sizes and algorithms (the prop harness shrinks failures).
//! Broadcasts must deliver every chunk to every rank causally exactly
//! once; reduction collectives must end with every rank's buffer
//! reflecting all n contributions exactly once (checked by the
//! generalized dataflow validator).

use gdrbcast::collectives::{
    self, validate::check_algorithm, Algorithm, BcastSpec, CollectiveKind, CollectiveSpec,
};
use gdrbcast::comm::Comm;
use gdrbcast::netsim::Engine;
use gdrbcast::topology::{presets, Cluster};
use gdrbcast::util::prop::{check, shrink_u64, shrink_usize, Config};
use gdrbcast::util::rng::Rng;

#[derive(Debug, Clone)]
struct Case {
    preset: u8,
    nodes: usize,
    gpn: usize,
    root: usize,
    bytes: u64,
    algo_idx: usize,
    chunk: u64,
    k: usize,
}

fn cluster_of(case: &Case) -> Cluster {
    match case.preset {
        0 => presets::kesch(case.nodes, case.gpn.clamp(1, 16)).unwrap(),
        1 => presets::dgx1(case.nodes, case.gpn.clamp(1, 8), false).unwrap(),
        2 => presets::dgx1(case.nodes, case.gpn.clamp(1, 8), true).unwrap(),
        _ => presets::flat(case.nodes * case.gpn).unwrap(),
    }
}

fn algo_of(case: &Case) -> Algorithm {
    match case.algo_idx % 6 {
        0 => Algorithm::Direct,
        1 => Algorithm::Chain,
        2 => Algorithm::PipelinedChain {
            chunk: case.chunk.max(1),
        },
        3 => Algorithm::Knomial {
            k: case.k.clamp(2, 8),
        },
        4 => Algorithm::ScatterRingAllgather,
        _ => Algorithm::HostStagedKnomial {
            k: case.k.clamp(2, 8),
        },
    }
}

fn gen_case(rng: &mut Rng) -> Case {
    Case {
        preset: rng.range_u64(0, 3) as u8,
        nodes: rng.range_usize(1, 3),
        gpn: rng.range_usize(1, 16),
        root: rng.range_usize(0, 63),
        bytes: rng.range_u64(0, 4 << 20),
        algo_idx: rng.range_usize(0, 5),
        chunk: rng.range_u64(1, 1 << 20),
        k: rng.range_usize(2, 8),
    }
}

fn shrink_case(c: &Case) -> Vec<Case> {
    let mut out = Vec::new();
    for nodes in shrink_usize(c.nodes, 1) {
        out.push(Case { nodes, ..c.clone() });
    }
    for gpn in shrink_usize(c.gpn, 1) {
        out.push(Case { gpn, ..c.clone() });
    }
    for bytes in shrink_u64(c.bytes, 0) {
        out.push(Case { bytes, ..c.clone() });
    }
    for chunk in shrink_u64(c.chunk, 1) {
        out.push(Case { chunk, ..c.clone() });
    }
    if c.root > 0 {
        out.push(Case {
            root: 0,
            ..c.clone()
        });
    }
    out
}

/// Every algorithm on every topology delivers every chunk to every rank,
/// causally, exactly once.
#[test]
fn prop_delivery_and_causality() {
    check(
        Config::default().cases(120),
        "bcast-delivery-causality",
        gen_case,
        |case| {
            let cluster = cluster_of(case);
            let n = cluster.n_gpus();
            let spec = BcastSpec::new(case.root % n, n, case.bytes);
            let algo = algo_of(case);
            let mut comm = Comm::new(&cluster);
            let mut engine = Engine::new(&cluster);
            check_algorithm(&algo, &mut comm, &mut engine, &spec)
                .map(|_| ())
                .map_err(|d| d.to_string())
        },
        shrink_case,
    );
}

/// Latency is non-decreasing in message size (same topology/algorithm).
#[test]
fn prop_latency_monotone_in_size() {
    check(
        Config::default().cases(60),
        "latency-monotone",
        gen_case,
        |case| {
            let cluster = cluster_of(case);
            let n = cluster.n_gpus();
            let algo = algo_of(case);
            let mut comm = Comm::new(&cluster);
            let mut engine = Engine::new(&cluster);
            let small = collectives::latency_ns(
                &algo,
                &mut comm,
                &mut engine,
                &BcastSpec::new(case.root % n, n, case.bytes / 2),
            );
            let large = collectives::latency_ns(
                &algo,
                &mut comm,
                &mut engine,
                &BcastSpec::new(case.root % n, n, case.bytes),
            );
            if small <= large {
                Ok(())
            } else {
                Err(format!("{small} > {large} for {}", algo_of(case).name()))
            }
        },
        shrink_case,
    );
}

/// Pipelined chain with C >= M equals the plain chain exactly.
#[test]
fn prop_pipelined_chain_degenerates_to_chain() {
    check(
        Config::default().cases(60),
        "pipelined-chain-degenerate",
        gen_case,
        |case| {
            let cluster = cluster_of(case);
            let n = cluster.n_gpus();
            let bytes = case.bytes.max(1);
            let spec = BcastSpec::new(case.root % n, n, bytes);
            let mut comm = Comm::new(&cluster);
            let mut engine = Engine::new(&cluster);
            let chain =
                collectives::latency_ns(&Algorithm::Chain, &mut comm, &mut engine, &spec);
            let piped = collectives::latency_ns(
                &Algorithm::PipelinedChain { chunk: bytes },
                &mut comm,
                &mut engine,
                &spec,
            );
            if chain == piped {
                Ok(())
            } else {
                Err(format!("chain {chain} != pipelined(C=M) {piped}"))
            }
        },
        shrink_case,
    );
}

/// The simulator is deterministic: same case, same answer.
#[test]
fn prop_deterministic() {
    check(
        Config::default().cases(40),
        "deterministic",
        gen_case,
        |case| {
            let cluster = cluster_of(case);
            let n = cluster.n_gpus();
            let spec = BcastSpec::new(case.root % n, n, case.bytes);
            let algo = algo_of(case);
            let mut comm = Comm::new(&cluster);
            let mut engine = Engine::new(&cluster);
            let a = collectives::latency_ns(&algo, &mut comm, &mut engine, &spec);
            let b = collectives::latency_ns(&algo, &mut comm, &mut engine, &spec);
            if a == b {
                Ok(())
            } else {
                Err(format!("{a} != {b}"))
            }
        },
        shrink_case,
    );
}

/// The reduction algorithm a case maps to, honouring each kind's menu.
fn reduction_algo_of(case: &Case) -> (Algorithm, CollectiveKind) {
    match case.algo_idx % 4 {
        0 => (Algorithm::RingReduceScatter, CollectiveKind::ReduceScatter),
        1 => (Algorithm::RingAllgather, CollectiveKind::Allgather),
        2 => (Algorithm::RingAllreduce, CollectiveKind::Allreduce),
        _ => (
            Algorithm::TreeAllreduce {
                k: case.k.clamp(2, 8),
            },
            CollectiveKind::Allreduce,
        ),
    }
}

/// Every reduction collective, on every topology, leaves every required
/// final buffer reflecting all n contributions exactly once — across
/// random roots, rank counts and (chunk-inducing) message sizes.
#[test]
fn prop_reductions_all_contributions_exactly_once() {
    check(
        Config::default().cases(120),
        "reduction-dataflow",
        gen_case,
        |case| {
            let cluster = cluster_of(case);
            let n = cluster.n_gpus();
            let (algo, kind) = reduction_algo_of(case);
            let spec = CollectiveSpec::collective(kind, case.root % n, n, case.bytes);
            let mut comm = Comm::new(&cluster);
            let mut engine = Engine::new(&cluster);
            check_algorithm(&algo, &mut comm, &mut engine, &spec)
                .map(|_| ())
                .map_err(|d| d.to_string())
        },
        shrink_case,
    );
}

/// Reduction latency is non-decreasing in message size.
#[test]
fn prop_reduction_latency_monotone_in_size() {
    check(
        Config::default().cases(60),
        "reduction-latency-monotone",
        gen_case,
        |case| {
            let cluster = cluster_of(case);
            let n = cluster.n_gpus();
            let (algo, kind) = reduction_algo_of(case);
            let mut comm = Comm::new(&cluster);
            let mut engine = Engine::new(&cluster);
            let small = collectives::latency_ns(
                &algo,
                &mut comm,
                &mut engine,
                &CollectiveSpec::collective(kind, case.root % n, n, case.bytes / 2),
            );
            let large = collectives::latency_ns(
                &algo,
                &mut comm,
                &mut engine,
                &CollectiveSpec::collective(kind, case.root % n, n, case.bytes),
            );
            if small <= large {
                Ok(())
            } else {
                Err(format!("{small} > {large} for {}", algo.name()))
            }
        },
        shrink_case,
    );
}

/// Ring allreduce moves exactly 2·(n−1)/n × M per rank: its total
/// traffic is 2·(n−1)·M-ish (segment rounding aside) — the
/// bandwidth-optimality the modern gradient exchange is built on.
#[test]
fn prop_ring_allreduce_traffic_bandwidth_optimal() {
    check(
        Config::default().cases(60),
        "ring-allreduce-traffic",
        gen_case,
        |case| {
            let cluster = cluster_of(case);
            let n = cluster.n_gpus();
            if n < 2 {
                return Ok(());
            }
            let bytes = case.bytes.max(n as u64);
            let spec = CollectiveSpec::allreduce(n, bytes);
            let mut comm = Comm::new(&cluster);
            let bp = collectives::plan(&Algorithm::RingAllreduce, &mut comm, &spec);
            let total = bp.plan.total_bytes();
            let expect = 2 * (n as u64 - 1) * bytes;
            // staged hops double-count their relay leg; rounding loses at
            // most n bytes — accept [expect - n, 2×expect]
            if total + n as u64 >= expect && total <= 2 * expect {
                Ok(())
            } else {
                Err(format!("moved {total} bytes, expected ~{expect} (n={n}, M={bytes})"))
            }
        },
        shrink_case,
    );
}

/// Total transfer volume is at least ~M×(n-1): every non-root rank must
/// receive the full message at least once.
#[test]
fn prop_traffic_lower_bound() {
    check(
        Config::default().cases(60),
        "traffic-lower-bound",
        gen_case,
        |case| {
            let cluster = cluster_of(case);
            let n = cluster.n_gpus();
            if n < 2 {
                return Ok(());
            }
            let bytes = case.bytes.max(n as u64); // avoid rounding noise
            let spec = BcastSpec::new(case.root % n, n, bytes);
            let algo = algo_of(case);
            let mut comm = Comm::new(&cluster);
            let bp = collectives::plan(&algo, &mut comm, &spec);
            let total = bp.plan.total_bytes();
            let min = bytes * (n as u64 - 1) - n as u64; // slack for part rounding
            if total >= min {
                Ok(())
            } else {
                Err(format!(
                    "{} moved only {total} bytes (< {min}) for M={bytes} n={n}",
                    algo.name()
                ))
            }
        },
        shrink_case,
    );
}
