//! Structured-fabric integration tests: algebraic-vs-BFS route parity
//! on small instances of every fabric family, route-table sparsity
//! under planning, the kill_link recovery path (generation bump, PL005
//! stale flags, BFS detour around the dead link), and an end-to-end
//! plan/execute pass on each fabric under both link models.

use gdrbcast::analysis::{self, Code};
use gdrbcast::collectives::{self, Algorithm, BcastSpec};
use gdrbcast::comm::Comm;
use gdrbcast::netsim::{Engine, LinkModel};
use gdrbcast::topology::presets::{dragonfly, fat_tree, nvswitch, rail_optimized};
use gdrbcast::topology::Cluster;

/// Small instances of every fabric family, labelled for failure
/// messages. Shapes are chosen so each family exercises asymmetric
/// parameters at least once (non-square pods, single rail, >2 nodes).
fn small_fabrics() -> Vec<(&'static str, Cluster)> {
    vec![
        ("fat_tree(2,2,2,2,2)", fat_tree(2, 2, 2, 2, 2).unwrap()),
        ("fat_tree(3,2,2,1,2)", fat_tree(3, 2, 2, 1, 2).unwrap()),
        ("rail_optimized(3,4)", rail_optimized(3, 4).unwrap()),
        ("nvswitch(3,4)", nvswitch(3, 4).unwrap()),
        ("dragonfly(3,2,2)", dragonfly(3, 2, 2).unwrap()),
    ]
}

/// The parity invariant: for every ordered GPU pair, the algebraic
/// route must match the BFS golden reference in hop count, latency and
/// bottleneck bandwidth. (The exact hop sequence may differ — rail and
/// spine selection is a tie-break among equal-cost paths — so parity is
/// on the route *metrics*, which is what the simulation consumes.)
#[test]
fn algebraic_routes_match_bfs_reference_on_every_fabric() {
    for (name, c) in small_fabrics() {
        assert!(
            c.has_algebraic_resolver(),
            "{name}: generator must install an algebraic resolver"
        );
        let mut golden = c.clone();
        golden.force_bfs_resolver();
        for i in 0..c.n_gpus() {
            for j in 0..c.n_gpus() {
                if i == j {
                    continue;
                }
                let (a, b) = (c.rank_device(i), c.rank_device(j));
                let alg = c.route_info(a, b).unwrap();
                let bfs = golden.route_info(a, b).unwrap();
                assert_eq!(
                    alg.hops.len(),
                    bfs.hops.len(),
                    "{name}: hop count diverges from BFS on rank pair ({i}, {j})"
                );
                assert_eq!(
                    alg.latency_ns, bfs.latency_ns,
                    "{name}: latency diverges from BFS on rank pair ({i}, {j})"
                );
                assert_eq!(
                    alg.bottleneck_bw, bfs.bottleneck_bw,
                    "{name}: bottleneck bandwidth diverges from BFS on rank pair ({i}, {j})"
                );
            }
        }
    }
}

/// Every algebraic route must be a contiguous directed path from its
/// source to its destination — the same invariant the PL017 verifier
/// walk enforces, checked here directly against the resolver output.
#[test]
fn algebraic_routes_are_contiguous_paths() {
    for (name, c) in small_fabrics() {
        for i in 0..c.n_gpus() {
            for j in 0..c.n_gpus() {
                if i == j {
                    continue;
                }
                let (a, b) = (c.rank_device(i), c.rank_device(j));
                let r = c.route_info(a, b).unwrap();
                let mut at = a;
                for (k, &h) in r.hops.iter().enumerate() {
                    let link = c.link(h);
                    assert_eq!(
                        link.src, at,
                        "{name}: hop {k} of rank pair ({i}, {j}) departs the wrong device"
                    );
                    at = link.dst;
                }
                assert_eq!(at, b, "{name}: path of rank pair ({i}, {j}) ends off-target");
            }
        }
    }
}

/// Planning a broadcast must intern O(n) routes, not the O(n^2) dense
/// table — the property that lets the 64k-GPU bench row exist at all.
#[test]
fn planning_interns_a_sparse_route_table() {
    let c = fat_tree(2, 4, 8, 2, 2).unwrap();
    let n = c.n_gpus();
    assert_eq!(n, 64);
    let mut comm = Comm::new(&c);
    let bp = collectives::plan(&Algorithm::Chain, &mut comm, &BcastSpec::new(0, n, 1 << 20));
    assert!(!bp.plan.is_empty());
    let n_routes = c.routes().n_routes();
    assert!(
        n_routes <= 4 * n,
        "chain broadcast on {n} GPUs interned {n_routes} routes (expected O(n))"
    );
}

/// The satellite recovery scenario: killing a link on an
/// algebraic-resolver topology must (a) bump the topology generation so
/// pre-kill plans verify stale (PL005), and (b) make re-resolution of
/// the victim pair fall back to BFS around the dead link — while the
/// algebraic resolver stays installed for unaffected pairs.
#[test]
fn kill_link_on_algebraic_fabric_flags_stale_plans_and_detours() {
    let mut c = fat_tree(2, 2, 2, 2, 2).unwrap();
    let n = c.n_gpus();
    let stale_plan = {
        let mut comm = Comm::new(&c);
        collectives::plan(&Algorithm::Chain, &mut comm, &BcastSpec::new(0, n, 1 << 20))
    };
    let (a, b) = (c.rank_device(0), c.rank_device(1));
    let pre = c.route_info(a, b).unwrap();
    assert_eq!(pre.hops.len(), 2, "same-leaf pair is 2 hops pre-kill");
    // kill rank 0's rail-0 uplink (the first hop of the algebraic route)
    let victim = pre.hops[0];
    let gen_before = c.generation();
    c.kill_link(victim).unwrap();
    assert_ne!(
        c.generation(),
        gen_before,
        "kill_link must bump the topology generation"
    );
    assert!(
        c.has_algebraic_resolver(),
        "the resolver survives the kill; only the victim pair detours"
    );

    // (a) the pre-kill plan is stale: every transfer's RouteId was
    // interned under the old generation
    let diags = analysis::verify_collective(&c, &stale_plan);
    assert!(
        diags.iter().any(|d| d.code == Code::StaleRoute),
        "pre-kill plan must be flagged PL005-stale, got: {diags:?}"
    );
    assert_eq!(Code::StaleRoute.as_str(), "PL005");

    // (b) re-resolving the victim pair detours via BFS: same-leaf
    // connectivity survives on rail 1, so the pair stays 2 hops but
    // avoids the dead link
    let post = c.route_info(a, b).unwrap();
    assert!(
        !post.hops.contains(&victim),
        "re-resolved route must avoid the dead link"
    );
    assert_eq!(post.hops.len(), 2, "rail 1 keeps the pair at 2 hops");
    let mut at = a;
    for &h in &post.hops {
        assert!(c.link_alive(h));
        assert_eq!(c.link(h).src, at);
        at = c.link(h).dst;
    }
    assert_eq!(at, b);

    // a plan rebuilt on the mutated topology verifies clean
    let rebuilt = {
        let mut comm = Comm::new(&c);
        collectives::plan(&Algorithm::Chain, &mut comm, &BcastSpec::new(0, n, 1 << 20))
    };
    let diags = analysis::verify_collective(&c, &rebuilt);
    assert!(
        !analysis::has_errors(&diags),
        "rebuilt plan must verify clean: {}",
        analysis::render(&diags)
    );
}

/// End to end on every fabric: a chain broadcast plans, verifies clean,
/// and executes to a positive, deterministic makespan under both link
/// models.
#[test]
fn every_fabric_plans_and_executes_under_both_link_models() {
    for (name, c) in small_fabrics() {
        let n = c.n_gpus();
        let mut comm = Comm::new(&c);
        let bp = collectives::plan(&Algorithm::Chain, &mut comm, &BcastSpec::new(0, n, 1 << 20));
        let diags = analysis::verify_collective(&c, &bp);
        assert!(
            !analysis::has_errors(&diags),
            "{name}: {}",
            analysis::render(&diags)
        );
        for model in [LinkModel::Fifo, LinkModel::FairShare] {
            let mut engine = Engine::with_model(&c, model);
            let first = engine.makespan_ns(&bp.plan);
            assert!(first > 0, "{name}: zero makespan under {}", model.name());
            let again = engine.makespan_ns(&bp.plan);
            assert_eq!(
                first,
                again,
                "{name}: makespan not reproducible under {}",
                model.name()
            );
        }
    }
}
