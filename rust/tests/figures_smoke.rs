//! Figure-level smoke tests: small versions of F1/F2 asserting the
//! paper's qualitative results (who wins, where) without running the
//! full sweeps.

use gdrbcast::bench::osu::osu_bcast;
use gdrbcast::bench::report::Figure;
use gdrbcast::collectives::BcastSpec;
use gdrbcast::comm::Comm;
use gdrbcast::nccl::{bcast as nccl_bcast, hierarchical, NcclParams};
use gdrbcast::netsim::Engine;
use gdrbcast::topology::presets;
use gdrbcast::tuning::Selector;

fn fig1(gpus: usize, sizes: &[u64]) -> Figure {
    let cluster = presets::kesch(1, gpus).unwrap();
    let selector = Selector::tuned(&cluster);
    let nccl = NcclParams::default();
    let mut comm = Comm::new(&cluster);
    let mut engine = Engine::new(&cluster);
    let nccl_res = osu_bcast(&mut engine, sizes, 2, 1, |bytes, _| {
        nccl_bcast::plan_intranode(&cluster, &nccl, &BcastSpec::new(0, gpus, bytes))
    });
    let mv2_res = osu_bcast(&mut engine, sizes, 2, 1, |bytes, _| {
        selector.plan(&mut comm, &BcastSpec::new(0, gpus, bytes))
    });
    let mut fig = Figure::new(format!("{gpus} gpus"), sizes.to_vec());
    fig.push_series("NCCL", nccl_res.iter().map(|r| r.latency_us).collect());
    fig.push_series("MV2-GDR-Opt", mv2_res.iter().map(|r| r.latency_us).collect());
    fig
}

#[test]
fn fig1_shape_small_messages_win_big_large_comparable() {
    let sizes = [4u64, 512, 8 << 10, 1 << 20, 32 << 20, 128 << 20];
    for gpus in [2usize, 4, 8, 16] {
        let fig = fig1(gpus, &sizes);
        let (_, small_ratio) = fig.max_ratio_below(8 << 10).unwrap();
        assert!(
            small_ratio > 5.0,
            "{gpus} GPUs: small-message win only {small_ratio:.1}x (paper: 9.4x-14x)"
        );
        let large_ratio = fig.ratio_at_max().unwrap();
        assert!(
            (0.7..3.0).contains(&large_ratio),
            "{gpus} GPUs: large messages must be comparable, got {large_ratio:.2}x"
        );
        // and the small-message win must exceed the large-message one —
        // the size-dependence the whole paper hinges on
        assert!(small_ratio > large_ratio);
    }
}

#[test]
fn fig2_shape_internode() {
    let sizes = [4u64, 8 << 10, 1 << 20, 64 << 20];
    for nodes in [2usize, 4] {
        let cluster = presets::kesch(nodes, 16).unwrap();
        let gpus = cluster.n_gpus();
        let selector = Selector::tuned(&cluster);
        let nccl = NcclParams::default();
        let mut comm = Comm::new(&cluster);
        let mut engine = Engine::new(&cluster);
        let nccl_res = osu_bcast(&mut engine, &sizes, 2, 1, |bytes, _| {
            hierarchical::plan(
                &mut comm,
                &nccl,
                &BcastSpec::new(0, gpus, bytes),
                hierarchical::DEFAULT_CHUNK,
            )
        });
        let mv2_res = osu_bcast(&mut engine, &sizes, 2, 1, |bytes, _| {
            selector.plan(&mut comm, &BcastSpec::new(0, gpus, bytes))
        });
        let mut fig = Figure::new(format!("{gpus} gpus"), sizes.to_vec());
        fig.push_series("NCCL-MV2-GDR", nccl_res.iter().map(|r| r.latency_us).collect());
        fig.push_series("MV2-GDR-Opt", mv2_res.iter().map(|r| r.latency_us).collect());
        let (_, small_ratio) = fig.max_ratio_below(8 << 10).unwrap();
        assert!(
            small_ratio > 4.0,
            "{gpus} GPUs: internode small win {small_ratio:.1}x (paper: up to 16.6x)"
        );
        let large_ratio = fig.ratio_at_max().unwrap();
        assert!(
            (0.7..3.0).contains(&large_ratio),
            "{gpus} GPUs: large internode should be comparable, got {large_ratio:.2}x"
        );
    }
}

#[test]
fn nccl_latency_flat_in_size_for_small_messages() {
    // the §II-B observation that motivates everything: NCCL's
    // small-message latency is launch-dominated — flat from 4B to 8KB
    let cluster = presets::kesch(1, 8).unwrap();
    let nccl = NcclParams::default();
    let mut engine = Engine::new(&cluster);
    let t4 = engine
        .execute(&nccl_bcast::plan_intranode(&cluster, &nccl, &BcastSpec::new(0, 8, 4)).plan)
        .makespan;
    let t8k = engine
        .execute(
            &nccl_bcast::plan_intranode(&cluster, &nccl, &BcastSpec::new(0, 8, 8 << 10)).plan,
        )
        .makespan;
    assert!(
        (t8k as f64) < (t4 as f64) * 1.2,
        "NCCL 8KB {t8k} should be ~= 4B {t4}"
    );
}
