//! Fair-share contention-model integration tests: link-capacity
//! conservation, single-flow parity with FIFO, divergence under real
//! contention, and FIFO golden parity across the algorithm × size ×
//! topology grid (the fair-share subsystem must be invisible when the
//! default model is selected).

use gdrbcast::collectives::{self, Algorithm, CollectiveSpec};
use gdrbcast::comm::Comm;
use gdrbcast::netsim::{maxmin_rates, Deps, Engine, LinkModel, Plan};
use gdrbcast::topology::presets;
use gdrbcast::tuning::Selector;

/// Deterministic xorshift (the repo's reference-test idiom).
struct Xs(u64);
impl Xs {
    fn next(&mut self) -> u64 {
        let mut x = self.0;
        x ^= x << 13;
        x ^= x >> 7;
        x ^= x << 17;
        self.0 = x;
        x
    }
}

fn grid_algorithms() -> Vec<Algorithm> {
    vec![
        Algorithm::Direct,
        Algorithm::Chain,
        Algorithm::PipelinedChain { chunk: 64 << 10 },
        Algorithm::Knomial { k: 2 },
        Algorithm::Knomial { k: 4 },
        Algorithm::ScatterRingAllgather,
        Algorithm::HostStagedKnomial { k: 2 },
        Algorithm::RingReduceScatter,
        Algorithm::RingAllgather,
        Algorithm::RingAllreduce,
        Algorithm::TreeAllreduce { k: 2 },
    ]
}

fn grid_topologies() -> Vec<(&'static str, gdrbcast::topology::Cluster)> {
    vec![
        ("flat(8)", presets::flat(8).unwrap()),
        ("kesch(1,8)", presets::kesch(1, 8).unwrap()),
        ("kesch(2,8)", presets::kesch(2, 8).unwrap()),
    ]
}

#[test]
fn maxmin_rates_conserve_link_capacity_on_kesch() {
    // the acceptance property: for random concurrent flow sets on the
    // paper's testbed topology, the sum of allocated rates on any link
    // never exceeds that link's bandwidth
    let cluster = presets::kesch(2, 16).unwrap();
    let n = cluster.n_gpus();
    let mut rng = Xs(0xfa15_eed1 | 1);
    for case in 0..50 {
        let n_flows = 2 + (rng.next() % 24) as usize;
        let mut flows = Vec::with_capacity(n_flows);
        for _ in 0..n_flows {
            let src = (rng.next() % n as u64) as usize;
            let mut dst = (rng.next() % n as u64) as usize;
            if dst == src {
                dst = (dst + 1) % n;
            }
            let route = cluster
                .route(cluster.rank_device(src), cluster.rank_device(dst))
                .unwrap();
            let cap = if rng.next() % 4 == 0 {
                Some(1.0e9 + (rng.next() % 8) as f64 * 1.0e9)
            } else {
                None
            };
            flows.push((route, cap));
        }
        let rates = maxmin_rates(&cluster, &flows);
        assert_eq!(rates.len(), flows.len());
        let mut per_link = vec![0.0f64; cluster.n_links()];
        for (i, &(route, cap)) in flows.iter().enumerate() {
            assert!(
                rates[i] > 0.0,
                "case {case}: flow {i} starved on a live fabric"
            );
            if let Some(cap) = cap {
                assert!(
                    rates[i] <= cap * (1.0 + 1e-9),
                    "case {case}: flow {i} exceeds its cap"
                );
            }
            for &h in cluster.route_view(route).hops.iter() {
                per_link[h.0] += rates[i];
            }
        }
        for (l, &used) in per_link.iter().enumerate() {
            let bw = cluster.links()[l].bandwidth;
            assert!(
                used <= bw * (1.0 + 1e-9),
                "case {case}: link {l} oversubscribed ({used} > {bw})"
            );
        }
    }
}

#[test]
fn single_mechanism_sends_match_fifo() {
    // one rank-to-rank send at a time — across the mechanism menu
    // (IPC, GDR, staged, eager) — costs exactly the same under both
    // models: a lone flow's max-min rate is the FIFO bottleneck
    let cluster = presets::kesch(2, 8).unwrap();
    let pairs = [(0usize, 1usize), (0, 4), (0, 8), (3, 12), (8, 15)];
    for &(src, dst) in &pairs {
        for bytes in [4u64, 64 << 10, 1 << 20, 16 << 20] {
            let mut comm = Comm::new(&cluster);
            let mut plan = Plan::new();
            comm.send(&mut plan, src, dst, bytes, Deps::none(), Some((dst, 0)));
            let fifo = Engine::new(&cluster).execute(&plan).makespan;
            let fair = Engine::with_model(&cluster, LinkModel::FairShare)
                .execute(&plan)
                .makespan;
            assert_eq!(
                fifo, fair,
                "lone send {src}->{dst} of {bytes}B diverged between models"
            );
        }
    }
}

#[test]
fn contended_fanout_diverges_and_fairshare_wins_the_star() {
    // a non-blocking star fan-out: the root issues 7 concurrent sends
    // over its single uplink. FIFO serializes them back-to-back (each
    // send additionally pays the issue gap); fair share drains all
    // flows together — strictly faster, and the models must *disagree*
    // (the serialized-contention fidelity bug this subsystem fixes).
    let cluster = presets::flat(8).unwrap();
    let n = cluster.n_gpus();
    let bytes: u64 = 16 << 20;
    let mut comm = Comm::new(&cluster);
    let mut plan = Plan::new();
    for dst in 1..n {
        comm.send(&mut plan, 0, dst, bytes, Deps::none(), Some((dst, 0)));
    }
    let fifo = Engine::new(&cluster).execute(&plan).makespan;
    let mut fair_engine = Engine::with_model(&cluster, LinkModel::FairShare);
    let fair = fair_engine.execute(&plan).makespan;
    assert_ne!(fifo, fair, "contended fan-out must distinguish the models");
    assert!(
        fair < fifo,
        "fair share must beat FIFO serialization on the star: {fair} vs {fifo}"
    );
    // and the shared uplink still bounds it: 7 concurrent 16 MB flows
    // over 10 GB/s cannot beat the aggregate-bytes bound
    let aggregate_floor = ((7 * bytes) as f64 / 10.0e9 * 1e9) as u64;
    assert!(
        fair >= aggregate_floor,
        "fair share under-charges the shared uplink: {fair} < {aggregate_floor}"
    );
    // every rank still gets its delivery recorded
    let r = fair_engine.execute(&plan);
    for dst in 1..n {
        assert!(r.delivery_time(&plan, dst, 0).is_some());
    }
}

#[test]
fn fifo_golden_parity_grid() {
    // the default model must be bit-identical whether selected
    // implicitly (Engine::new) or explicitly, across repeats and across
    // the recording/makespan-only paths, for every algorithm × size ×
    // topology — i.e. the fair-share subsystem changes nothing unless
    // asked for
    for (name, cluster) in &grid_topologies() {
        let n = cluster.n_gpus();
        let mut comm = Comm::new(cluster);
        let mut default_engine = Engine::new(cluster);
        let mut fifo_engine = Engine::with_model(cluster, LinkModel::Fifo);
        assert_eq!(default_engine.link_model(), LinkModel::Fifo);
        for algo in &grid_algorithms() {
            for bytes in [4u64, 64 << 10, 16 << 20] {
                let spec = CollectiveSpec::collective(algo.kind(), 0, n, bytes);
                let bp = collectives::plan(algo, &mut comm, &spec);
                let implicit = default_engine.execute(&bp.plan).makespan;
                let explicit = fifo_engine.execute(&bp.plan).makespan;
                let repeat = fifo_engine.execute(&bp.plan).makespan;
                let fast = fifo_engine.makespan_ns(&bp.plan);
                assert_eq!(implicit, explicit, "{name} {} {bytes}B", algo.name());
                assert_eq!(implicit, repeat, "{name} {} {bytes}B", algo.name());
                assert_eq!(implicit, fast, "{name} {} {bytes}B", algo.name());
            }
        }
    }
}

#[test]
fn fairshare_grid_is_deterministic_and_keeps_plans_valid() {
    // across the same grid: the fair-share engine is deterministic
    // (fresh engines agree, repeats agree, makespan-only agrees) and
    // the executed schedule still satisfies every collective invariant
    // (delivery, causality, dataflow) — the DAG semantics are untouched
    for (name, cluster) in &grid_topologies() {
        let n = cluster.n_gpus();
        let mut comm = Comm::new(cluster);
        let mut engine = Engine::with_model(cluster, LinkModel::FairShare);
        for algo in &grid_algorithms() {
            for bytes in [4u64, 64 << 10, 16 << 20] {
                let spec = CollectiveSpec::collective(algo.kind(), 0, n, bytes);
                let bp = collectives::plan(algo, &mut comm, &spec);
                let result = engine.execute(&bp.plan);
                collectives::validate::validate(&bp, &result).unwrap_or_else(|e| {
                    panic!("{name} {} {bytes}B invalid under fair share: {e}", algo.name())
                });
                let mut fresh = Engine::with_model(cluster, LinkModel::FairShare);
                assert_eq!(
                    result.makespan,
                    fresh.execute(&bp.plan).makespan,
                    "{name} {} {bytes}B nondeterministic",
                    algo.name()
                );
                assert_eq!(
                    result.makespan,
                    engine.makespan_ns(&bp.plan),
                    "{name} {} {bytes}B makespan-only diverged",
                    algo.name()
                );
            }
        }
    }
}

#[test]
fn fairshare_tuned_selector_round_trips_through_persist() {
    // a fair-share-tuned table keeps its model tag through the JSON
    // artifact, so a selector rebuilt from disk still knows which engine
    // it should dispatch for
    let cluster = presets::kesch(1, 4).unwrap();
    let sel = Selector::tuned_with_model(&cluster, Some(2), LinkModel::FairShare);
    assert_eq!(sel.link_model(), LinkModel::FairShare);
    let json = gdrbcast::tuning::persist::to_json(sel.table());
    let back = gdrbcast::tuning::persist::from_json(&json).unwrap();
    assert_eq!(back.link_model, LinkModel::FairShare);
    let restored = Selector::from_table(back);
    for bytes in [4u64, 1 << 20, 32 << 20] {
        assert_eq!(restored.algorithm(bytes), sel.algorithm(bytes));
    }
}
