//! Integration: the rust runtime executes the AOT-compiled JAX/Pallas
//! training step. Skipped (with a notice) when `make artifacts` has not
//! run yet.

use gdrbcast::coordinator::worker::ComputeBackend;
use gdrbcast::runtime::{Artifacts, PjrtWorker, Runtime, TrainStep};

fn artifacts() -> Option<Artifacts> {
    match Artifacts::discover() {
        Ok(a) => Some(a),
        Err(e) => {
            eprintln!("skipping PJRT e2e test: {e}");
            None
        }
    }
}

fn init_params(n: usize, seed: u64) -> Vec<f32> {
    let mut rng = gdrbcast::util::rng::Rng::new(seed);
    (0..n)
        .map(|_| (rng.next_f64() as f32 - 0.5) * 0.05)
        .collect()
}

#[test]
fn train_step_executes_and_learns() {
    let Some(arts) = artifacts() else { return };
    let rt = Runtime::cpu().expect("pjrt cpu client");
    let step = TrainStep::load(&rt, &arts).expect("compile train_step");
    assert_eq!(step.n_params, arts.meta.n_params);

    let mut params = init_params(step.n_params, 7);
    let worker = PjrtWorker::new(&step, 42, 1);
    let mut losses = Vec::new();
    for _ in 0..80 {
        let (x, y) = worker.batch();
        let (new_params, loss) = step.step(&params, x, y, 0.25).expect("step");
        assert!(loss.is_finite(), "loss must be finite");
        params = new_params;
        losses.push(loss);
    }
    let first: f32 = losses[..5].iter().sum::<f32>() / 5.0;
    let last: f32 = losses[losses.len() - 5..].iter().sum::<f32>() / 5.0;
    assert!(
        last < first * 0.9,
        "loss should decrease: first5 {first} last5 {last} ({losses:?})"
    );
}

#[test]
fn predict_artifact_loads_and_runs() {
    let Some(arts) = artifacts() else { return };
    let rt = Runtime::cpu().expect("pjrt cpu client");
    let exe = rt
        .load_hlo_text(&arts.predict_path())
        .expect("compile predict");
    let params = init_params(arts.meta.n_params, 3);
    let x = vec![0.1f32; arts.meta.batch * arts.meta.input_dim];
    let out = exe
        .run_f32(&[
            (&params, &[arts.meta.n_params as i64]),
            (&x, &[arts.meta.batch as i64, arts.meta.input_dim as i64]),
        ])
        .expect("run predict");
    assert_eq!(out.len(), arts.meta.batch * arts.meta.classes);
    assert!(out.iter().all(|v| v.is_finite()));
}

#[test]
fn pjrt_worker_gradients_average_correctly() {
    let Some(arts) = artifacts() else { return };
    let rt = Runtime::cpu().expect("pjrt cpu client");
    let step = TrainStep::load(&rt, &arts).expect("compile");
    let params = init_params(step.n_params, 11);
    let mut w1 = PjrtWorker::new(&step, 1, 5);
    let mut w2 = PjrtWorker::new(&step, 2, 5);
    let (g1, l1) = w1.grad(&params, 0);
    let (g2, l2) = w2.grad(&params, 0);
    assert_eq!(g1.len(), params.len());
    assert_eq!(g2.len(), params.len());
    assert!(l1.is_finite() && l2.is_finite());
    // different shards -> different gradients
    let diff = g1
        .iter()
        .zip(&g2)
        .filter(|(a, b)| (**a - **b).abs() > 1e-9)
        .count();
    assert!(diff > params.len() / 2, "shards should differ: {diff}");
    assert_eq!(w1.n_params(), params.len());
}
