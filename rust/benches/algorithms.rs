//! Per-algorithm ablation bench: simulated latency of every broadcast
//! design across the message range, plus chunk-size sensitivity for the
//! pipelined chain (the §IV-B tuning question), plus the wall-clock cost
//! of planning+simulating each algorithm (L3 hot-path budget).
//!
//! `cargo bench --bench algorithms`

use gdrbcast::analytic::{self, ModelParams};
use gdrbcast::bench::harness::Bencher;
use gdrbcast::collectives::{self, Algorithm, BcastSpec};
use gdrbcast::comm::{Comm, CommParams};
use gdrbcast::netsim::Engine;
use gdrbcast::topology::presets;
use gdrbcast::util::bytes::{format_size, format_us};
use gdrbcast::util::tablefmt::Table;

fn main() {
    let cluster = presets::kesch(2, 16).unwrap();
    let n = cluster.n_gpus();
    let mut comm = Comm::new(&cluster);
    let mut engine = Engine::new(&cluster);

    let algos = [
        Algorithm::Direct,
        Algorithm::Chain,
        Algorithm::Knomial { k: 2 },
        Algorithm::Knomial { k: 4 },
        Algorithm::Knomial { k: 8 },
        Algorithm::ScatterRingAllgather,
        Algorithm::HostStagedKnomial { k: 2 },
        Algorithm::PipelinedChain { chunk: 1 << 20 },
    ];
    let sizes: [u64; 5] = [4, 8 << 10, 512 << 10, 8 << 20, 128 << 20];

    let mut t = Table::new(&[
        "algorithm", "4", "8K", "512K", "8M", "128M",
    ])
    .with_title(format!("simulated bcast latency (us), {n} GPUs over 2 KESCH nodes"));
    for algo in &algos {
        let mut row = vec![algo.name()];
        for &bytes in &sizes {
            let t_ns =
                collectives::latency_ns(algo, &mut comm, &mut engine, &BcastSpec::new(0, n, bytes));
            row.push(format_us(t_ns as f64));
        }
        t.row(row);
    }
    print!("{}", t.render());

    // chunk-size sensitivity (Eq. 5's C) + the analytic optimum
    println!("\npipelined-chain chunk-size sweep, 64 MB over {n} GPUs:");
    let m = 64 << 20;
    for chunk in [64 << 10, 256 << 10, 1 << 20, 4 << 20, 16 << 20u64] {
        let t_ns = collectives::latency_ns(
            &Algorithm::PipelinedChain { chunk },
            &mut comm,
            &mut engine,
            &BcastSpec::new(0, n, m),
        );
        println!("  C={:>5}: {:>10} us", format_size(chunk), format_us(t_ns as f64));
    }
    let p = ModelParams::flat_rndv(&CommParams::default());
    println!(
        "  analytic C* (flat-fabric Eq. 5 optimum): {}",
        format_size(analytic::bcast::optimal_chunk(&p, n, m))
    );

    // wall-clock planning+simulation cost per algorithm
    println!();
    let mut bencher = Bencher::new();
    for algo in &algos {
        bencher.bench(&format!("plan+sim/{}/8M", algo.family()), || {
            collectives::latency_ns(algo, &mut comm, &mut engine, &BcastSpec::new(0, n, 8 << 20))
        });
    }
    bencher.write_report("algorithms").expect("report");
}
