//! Bench E1 — simulator vs the paper's analytic cost models
//! (Eqs. 1–5) on the idealised flat fabric, across the (n, M) grid.
//! This is the "Table" of §III made executable.
//!
//! `cargo bench --bench model_validation`

use gdrbcast::analytic::validate::run_grid;
use gdrbcast::collectives::Algorithm;
use gdrbcast::util::bytes::{format_size, format_us};
use gdrbcast::util::tablefmt::Table;

fn main() {
    let algos = [
        Algorithm::Direct,
        Algorithm::Chain,
        Algorithm::PipelinedChain { chunk: 256 << 10 },
        Algorithm::Knomial { k: 2 },
        Algorithm::Knomial { k: 4 },
        Algorithm::ScatterRingAllgather,
    ];
    let ns = [2usize, 4, 8, 16, 32, 64, 128];
    let sizes = [4u64, 8 << 10, 1 << 20, 16 << 20, 128 << 20];
    let rows = run_grid(&algos, &ns, &sizes);

    let mut t = Table::new(&["algorithm", "n", "M", "sim (us)", "model (us)", "rel err"])
        .with_title("E1 — simulator vs Eqs. (1)-(5), flat fabric");
    let mut worst = (0.0f64, String::new());
    let mut sum_err = 0.0;
    for r in &rows {
        if r.rel_err > worst.0 {
            worst = (
                r.rel_err,
                format!("{} n={} M={}", r.algorithm, r.n, format_size(r.bytes)),
            );
        }
        sum_err += r.rel_err;
        // print a representative subset (full grid goes to the JSON)
        if r.n >= 16 {
            t.row(vec![
                r.algorithm.clone(),
                r.n.to_string(),
                format_size(r.bytes),
                format_us(r.sim_ns),
                format_us(r.model_ns),
                format!("{:.4}", r.rel_err),
            ]);
        }
    }
    print!("{}", t.render());
    println!(
        "grid: {} points; mean rel err {:.4}; worst {:.4} ({})",
        rows.len(),
        sum_err / rows.len() as f64,
        worst.0,
        worst.1
    );

    // JSON dump
    use gdrbcast::util::json::Json;
    let arr: Vec<Json> = rows
        .iter()
        .map(|r| {
            let mut j = Json::obj();
            j.set("algorithm", r.algorithm.as_str())
                .set("n", r.n)
                .set("bytes", r.bytes)
                .set("sim_ns", r.sim_ns)
                .set("model_ns", r.model_ns)
                .set("rel_err", r.rel_err);
            j
        })
        .collect();
    std::fs::create_dir_all("target/reports").expect("reports dir");
    std::fs::write(
        "target/reports/model_validation.json",
        Json::Arr(arr).to_string_pretty(),
    )
    .expect("write");
    println!("full grid written to target/reports/model_validation.json");
}
