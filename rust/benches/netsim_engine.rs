//! Engine micro-bench: raw discrete-event throughput (ops/second) — the
//! L3 hot path that every figure sweep multiplies. §Perf tracks this
//! number before/after optimisation. Execution throughput is reported
//! under both link-contention models (the fair-share path re-levels
//! max-min rates on every flow event, so its constant is inherently
//! higher — the bench keeps the two honest side by side).
//!
//! `cargo bench --bench netsim_engine`

use gdrbcast::bench::harness::{link_models_from_env, one_shot_row, Bencher};
use gdrbcast::collectives::{self, Algorithm, BcastSpec};
use gdrbcast::comm::Comm;
use gdrbcast::netsim::{Engine, LinkModel, OpId, Plan, SimOp};
use gdrbcast::topology::{presets, Cluster};

fn main() {
    let mut bencher = Bencher::new();
    let models = link_models_from_env();

    // plan construction vs execution, separated
    let cluster = presets::kesch(8, 16).unwrap();
    let n = cluster.n_gpus();
    let mut comm = Comm::new(&cluster);
    let spec = BcastSpec::new(0, n, 128 << 20);
    let algo = Algorithm::PipelinedChain { chunk: 512 << 10 };

    let plan = collectives::plan(&algo, &mut comm, &spec);
    println!(
        "pipelined-chain 128M / 512K chunks / {n} GPUs -> {} ops",
        plan.plan.len()
    );

    bencher.bench("plan/pipelined-chain/128gpus/128M", || {
        collectives::plan(&algo, &mut comm, &spec).plan.len()
    });

    for &model in &models {
        let mut engine = Engine::with_model(&cluster, model);
        let r = bencher.bench(
            &format!("execute/pipelined-chain/128gpus/128M/{}", model.name()),
            || engine.execute(&plan.plan).makespan,
        );
        let ops_per_sec = plan.plan.len() as f64 / (r.per_iter.mean / 1e9);
        println!(
            "engine throughput [{}]: {:.1}M ops/s",
            model.name(),
            ops_per_sec / 1e6
        );

        // scaling with op count
        for chunk in [4u64 << 20, 1 << 20, 256 << 10, 64 << 10] {
            let a = Algorithm::PipelinedChain { chunk };
            let p = collectives::plan(&a, &mut comm, &spec);
            let label = format!("execute/{}ops/{}", p.plan.len(), model.name());
            bencher.bench(&label, || engine.execute(&p.plan).makespan);
        }
    }

    // fair-share event throughput: the incremental max-min solver vs the
    // full-recompute reference, one engine flipped via
    // set_full_recompute. Per-node chain broadcasts keep the flow
    // components disjoint across nodes, so the incremental ripple stays
    // local while the reference re-levels everything on every event.
    let ev_plan = per_node_chain_plan(&cluster, 8, 16, 16, 1 << 20);
    let events = 2 * ev_plan.len();
    let mut en = Engine::with_model(&cluster, LinkModel::FairShare);
    en.set_full_recompute(false);
    let r = bencher.bench("engine_events/kesch8x16/incremental", || {
        en.makespan_ns(&ev_plan)
    });
    let inc_ns = r.per_iter.mean;
    en.set_full_recompute(true);
    let r = bencher.bench("engine_events/kesch8x16/full", || en.makespan_ns(&ev_plan));
    let full_ns = r.per_iter.mean;
    let extra = vec![
        one_shot_row(
            "engine_events/kesch8x16/fairshare_events_per_sec",
            events as f64 / (inc_ns / 1e9),
        ),
        one_shot_row(
            "engine_events/kesch8x16/fairshare_full_events_per_sec",
            events as f64 / (full_ns / 1e9),
        ),
        one_shot_row(
            "engine_events/kesch8x16_incremental_vs_full",
            full_ns / inc_ns.max(1.0),
        ),
    ];
    println!(
        "fair-share events kesch(8x16): {:.2}M ev/s incremental vs {:.2}M ev/s full ({:.2}x)",
        events as f64 / (inc_ns / 1e9) / 1e6,
        events as f64 / (full_ns / 1e9) / 1e6,
        full_ns / inc_ns.max(1.0)
    );

    // full figure-sweep budget check (DESIGN.md: F1+F2 sweep < 10 s)
    let t0 = std::time::Instant::now();
    let sizes = gdrbcast::util::bytes::pow2_sweep(4, 128 << 20);
    for gpus in [2usize, 4, 8, 16] {
        let c = presets::kesch(1, gpus).unwrap();
        let sel = gdrbcast::tuning::Selector::tuned(&c);
        let mut cm = Comm::new(&c);
        let mut en = Engine::new(&c);
        for &bytes in &sizes {
            let _ = sel.latency_ns(&mut cm, &mut en, &BcastSpec::new(0, gpus, bytes));
        }
    }
    println!(
        "fig1-shaped tuned sweep (4 GPU counts x {} sizes incl. tuning): {:.2}s",
        sizes.len(),
        t0.elapsed().as_secs_f64()
    );

    bencher
        .write_report_with("netsim_engine", extra)
        .expect("report");
}

/// See `sweep_perf`'s twin: per-node chunked chain broadcasts whose flow
/// components never cross node boundaries.
fn per_node_chain_plan(
    cluster: &Cluster,
    nodes: usize,
    gpn: usize,
    chunks: usize,
    bytes: u64,
) -> Plan {
    let mut plan = Plan::new();
    for node in 0..nodes {
        let base = node * gpn;
        for chunk in 0..chunks {
            let mut left: Option<OpId> = None;
            for i in 0..gpn - 1 {
                let route = cluster
                    .route(
                        cluster.rank_device(base + i),
                        cluster.rank_device(base + i + 1),
                    )
                    .expect("intra-node route");
                let id = plan.push(
                    SimOp::Transfer {
                        route,
                        bytes: bytes + (chunk as u64) * 65536,
                        overhead_ns: 1000,
                        issue_ns: 1000,
                        bw_cap: None,
                    },
                    left,
                    None,
                );
                left = Some(id);
            }
        }
    }
    plan
}
