//! Engine micro-bench: raw discrete-event throughput (ops/second) — the
//! L3 hot path that every figure sweep multiplies. §Perf tracks this
//! number before/after optimisation. Execution throughput is reported
//! under both link-contention models (the fair-share path re-levels
//! max-min rates on every flow event, so its constant is inherently
//! higher — the bench keeps the two honest side by side).
//!
//! `cargo bench --bench netsim_engine`

use gdrbcast::bench::harness::{link_models_from_env, Bencher};
use gdrbcast::collectives::{self, Algorithm, BcastSpec};
use gdrbcast::comm::Comm;
use gdrbcast::netsim::Engine;
use gdrbcast::topology::presets;

fn main() {
    let mut bencher = Bencher::new();
    let models = link_models_from_env();

    // plan construction vs execution, separated
    let cluster = presets::kesch(8, 16);
    let n = cluster.n_gpus();
    let mut comm = Comm::new(&cluster);
    let spec = BcastSpec::new(0, n, 128 << 20);
    let algo = Algorithm::PipelinedChain { chunk: 512 << 10 };

    let plan = collectives::plan(&algo, &mut comm, &spec);
    println!(
        "pipelined-chain 128M / 512K chunks / {n} GPUs -> {} ops",
        plan.plan.len()
    );

    bencher.bench("plan/pipelined-chain/128gpus/128M", || {
        collectives::plan(&algo, &mut comm, &spec).plan.len()
    });

    for &model in &models {
        let mut engine = Engine::with_model(&cluster, model);
        let r = bencher.bench(
            &format!("execute/pipelined-chain/128gpus/128M/{}", model.name()),
            || engine.execute(&plan.plan).makespan,
        );
        let ops_per_sec = plan.plan.len() as f64 / (r.per_iter.mean / 1e9);
        println!(
            "engine throughput [{}]: {:.1}M ops/s",
            model.name(),
            ops_per_sec / 1e6
        );

        // scaling with op count
        for chunk in [4u64 << 20, 1 << 20, 256 << 10, 64 << 10] {
            let a = Algorithm::PipelinedChain { chunk };
            let p = collectives::plan(&a, &mut comm, &spec);
            let label = format!("execute/{}ops/{}", p.plan.len(), model.name());
            bencher.bench(&label, || engine.execute(&p.plan).makespan);
        }
    }

    // full figure-sweep budget check (DESIGN.md: F1+F2 sweep < 10 s)
    let t0 = std::time::Instant::now();
    let sizes = gdrbcast::util::bytes::pow2_sweep(4, 128 << 20);
    for gpus in [2usize, 4, 8, 16] {
        let c = presets::kesch(1, gpus);
        let sel = gdrbcast::tuning::Selector::tuned(&c);
        let mut cm = Comm::new(&c);
        let mut en = Engine::new(&c);
        for &bytes in &sizes {
            let _ = sel.latency_ns(&mut cm, &mut en, &BcastSpec::new(0, gpus, bytes));
        }
    }
    println!(
        "fig1-shaped tuned sweep (4 GPU counts x {} sizes incl. tuning): {:.2}s",
        sizes.len(),
        t0.elapsed().as_secs_f64()
    );

    bencher.write_report("netsim_engine").expect("report");
}
