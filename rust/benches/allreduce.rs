//! Allreduce ablation bench: simulated latency of the ring and tree
//! allreduce designs across the message range on the KESCH presets, the
//! ring/tree crossover the tuning framework exploits, plus the
//! wall-clock cost of planning+simulating each design (L3 hot-path
//! budget). Emits the same JSON report shape as `benches/algorithms.rs`
//! (`target/reports/allreduce.json`).
//!
//! `cargo bench --bench allreduce`

use gdrbcast::bench::harness::Bencher;
use gdrbcast::collectives::{self, Algorithm, CollectiveSpec};
use gdrbcast::comm::Comm;
use gdrbcast::netsim::Engine;
use gdrbcast::topology::presets;
use gdrbcast::util::bytes::{format_size, format_us, pow2_sweep};
use gdrbcast::util::tablefmt::Table;

fn algos() -> [Algorithm; 4] {
    [
        Algorithm::RingAllreduce,
        Algorithm::TreeAllreduce { k: 2 },
        Algorithm::TreeAllreduce { k: 4 },
        Algorithm::TreeAllreduce { k: 8 },
    ]
}

fn main() {
    let sizes: [u64; 6] = [4, 8 << 10, 512 << 10, 8 << 20, 64 << 20, 256 << 20];

    // simulated latency tables over the kesch presets
    for (nodes, gpn) in [(1usize, 8usize), (1, 16), (2, 16)] {
        let cluster = presets::kesch(nodes, gpn).unwrap();
        let n = cluster.n_gpus();
        let mut comm = Comm::new(&cluster);
        let mut engine = Engine::new(&cluster);
        let mut t = Table::new(&["algorithm", "4", "8K", "512K", "8M", "64M", "256M"])
            .with_title(format!(
                "simulated allreduce latency (us), {n} GPUs over {nodes} KESCH node(s)"
            ));
        for algo in &algos() {
            let mut row = vec![algo.name()];
            for &bytes in &sizes {
                let t_ns = collectives::latency_ns(
                    algo,
                    &mut comm,
                    &mut engine,
                    &CollectiveSpec::allreduce(n, bytes),
                );
                row.push(format_us(t_ns as f64));
            }
            t.row(row);
        }
        print!("{}", t.render());
        println!();
    }

    // the ring/tree crossover the tuner keys on: full 4 B – 256 MB sweep
    let cluster = presets::kesch(2, 16).unwrap();
    let n = cluster.n_gpus();
    let mut comm = Comm::new(&cluster);
    let mut engine = Engine::new(&cluster);
    let mut crossover: Option<u64> = None;
    for bytes in pow2_sweep(4, 256 << 20) {
        let spec = CollectiveSpec::allreduce(n, bytes);
        let ring = collectives::latency_ns(
            &Algorithm::RingAllreduce,
            &mut comm,
            &mut engine,
            &spec,
        );
        let tree = collectives::latency_ns(
            &Algorithm::TreeAllreduce { k: 2 },
            &mut comm,
            &mut engine,
            &spec,
        );
        if ring <= tree && crossover.is_none() {
            crossover = Some(bytes);
        }
    }
    match crossover {
        Some(bytes) => println!(
            "ring overtakes tree(k=2) at {} over {n} GPUs",
            format_size(bytes)
        ),
        None => println!("tree(k=2) never lost to ring up to 256M over {n} GPUs"),
    }

    // wall-clock planning+simulation cost per design
    println!();
    let mut bencher = Bencher::new();
    for algo in &algos() {
        bencher.bench(&format!("plan+sim/{}/8M", algo.name()), || {
            collectives::latency_ns(
                algo,
                &mut comm,
                &mut engine,
                &CollectiveSpec::allreduce(n, 8 << 20),
            )
        });
    }
    bencher.write_report("allreduce").expect("report");
}
