//! Bench F2 — regenerates the paper's Figure 2 (internode broadcast,
//! NCCL-MV2-GDR vs MV2-GDR-Opt on 2/4/8 KESCH nodes = 32/64/128 GPUs).
//!
//! Each scale is reported under every link-contention model (FIFO vs
//! max-min fair share) side by side; `LINK_MODEL=fifo|fairshare`
//! restricts a run to one model.
//!
//! `cargo bench --bench fig2_internode`

use gdrbcast::bench::harness::{link_models_from_env, Bencher};
use gdrbcast::bench::osu::osu_bcast;
use gdrbcast::bench::report::Figure;
use gdrbcast::collectives::BcastSpec;
use gdrbcast::comm::Comm;
use gdrbcast::nccl::{hierarchical, NcclParams};
use gdrbcast::netsim::Engine;
use gdrbcast::topology::presets;
use gdrbcast::tuning::Selector;
use gdrbcast::util::bytes::pow2_sweep;

fn main() {
    let sizes = pow2_sweep(4, 128 << 20);
    let nccl_params = NcclParams::default();
    let mut bencher = Bencher::new();
    let models = link_models_from_env();

    println!("== Figure 2: internode broadcast latency (KESCH, 16 GPUs/node) ==\n");
    for nodes in [2usize, 4, 8] {
        let cluster = presets::kesch(nodes, 16).unwrap();
        let gpus = cluster.n_gpus();
        for &model in &models {
            let selector = Selector::tuned_with_model(&cluster, None, model);
            let mut comm = Comm::new(&cluster);
            let mut engine = Engine::with_model(&cluster, model);

            let nccl_res = osu_bcast(&mut engine, &sizes, 2, 1, |bytes, _| {
                hierarchical::plan(
                    &mut comm,
                    &nccl_params,
                    &BcastSpec::new(0, gpus, bytes),
                    hierarchical::DEFAULT_CHUNK,
                )
            });
            let mv2_res = osu_bcast(&mut engine, &sizes, 2, 1, |bytes, _| {
                selector.plan(&mut comm, &BcastSpec::new(0, gpus, bytes))
            });

            let mut fig = Figure::new(
                format!("{gpus} GPUs ({nodes} nodes, {} link model)", model.name()),
                sizes.clone(),
            );
            fig.push_series(
                "NCCL-MV2-GDR",
                nccl_res.iter().map(|r| r.latency_us).collect(),
            );
            fig.push_series("MV2-GDR-Opt", mv2_res.iter().map(|r| r.latency_us).collect());
            print!("{}", fig.render());
            let (at, ratio) = fig.max_ratio_below(8 << 10).unwrap();
            let large = fig.ratio_at_max().unwrap();
            println!(
                "  => [{}] up to {ratio:.1}x at {at}B (small/medium); {large:.2}x at 128M (large)\n",
                model.name()
            );

            bencher.bench(&format!("sim/fig2/{gpus}gpus/4B/tuned/{}", model.name()), || {
                selector.latency_ns(&mut comm, &mut engine, &BcastSpec::new(0, gpus, 4))
            });
        }
    }
    bencher.write_report("fig2_internode").expect("report");
    println!("\npaper reference: up to 16.4X @64 GPUs / 16.6X @128 GPUs (small/medium), comparable at large sizes");
}
