//! Bench F1 — regenerates the paper's Figure 1 (intranode broadcast,
//! NCCL vs MV2-GDR-Opt on one KESCH node, 2/4/8/16 GPUs) and measures
//! the wall-clock cost of simulating it (the L3 hot path).
//!
//! Each scale is reported under every link-contention model (FIFO
//! serialized occupancy vs max-min fair share — DESIGN.md §Contention
//! models) side by side; the tuned selector is re-tuned per model so its
//! picks are consistent with the engine judging them. `LINK_MODEL=fifo`
//! (or `fairshare`) restricts a run to one model.
//!
//! `cargo bench --bench fig1_intranode`

use gdrbcast::bench::harness::{link_models_from_env, Bencher};
use gdrbcast::bench::osu::osu_bcast;
use gdrbcast::bench::report::Figure;
use gdrbcast::collectives::BcastSpec;
use gdrbcast::comm::Comm;
use gdrbcast::nccl::{bcast as nccl_bcast, NcclParams};
use gdrbcast::netsim::Engine;
use gdrbcast::topology::presets;
use gdrbcast::tuning::Selector;
use gdrbcast::util::bytes::pow2_sweep;

fn main() {
    let sizes = pow2_sweep(4, 128 << 20);
    let nccl_params = NcclParams::default();
    let mut bencher = Bencher::new();
    let models = link_models_from_env();

    println!("== Figure 1: intranode broadcast latency (KESCH node) ==\n");
    for gpus in [2usize, 4, 8, 16] {
        let cluster = presets::kesch(1, gpus).unwrap();
        for &model in &models {
            let selector = Selector::tuned_with_model(&cluster, None, model);
            let mut comm = Comm::new(&cluster);
            let mut engine = Engine::with_model(&cluster, model);

            let nccl_res = osu_bcast(&mut engine, &sizes, 3, 1, |bytes, _| {
                nccl_bcast::plan_intranode(
                    &cluster,
                    &nccl_params,
                    &BcastSpec::new(0, gpus, bytes),
                )
            });
            let mv2_res = osu_bcast(&mut engine, &sizes, 3, 1, |bytes, _| {
                selector.plan(&mut comm, &BcastSpec::new(0, gpus, bytes))
            });

            let mut fig = Figure::new(
                format!("{gpus} GPUs ({} link model)", model.name()),
                sizes.clone(),
            );
            fig.push_series("NCCL", nccl_res.iter().map(|r| r.latency_us).collect());
            fig.push_series("MV2-GDR-Opt", mv2_res.iter().map(|r| r.latency_us).collect());
            print!("{}", fig.render());
            let (at, ratio) = fig.max_ratio_below(8 << 10).unwrap();
            let large = fig.ratio_at_max().unwrap();
            println!(
                "  => [{}] up to {ratio:.1}x at {at}B (small/medium); {large:.2}x at 128M (large)\n",
                model.name()
            );

            // wall-clock of the simulation itself (perf target: DESIGN.md)
            bencher.bench(&format!("sim/fig1/{gpus}gpus/4B/tuned/{}", model.name()), || {
                selector.latency_ns(&mut comm, &mut engine, &BcastSpec::new(0, gpus, 4))
            });
            bencher.bench(
                &format!("sim/fig1/{gpus}gpus/128M/tuned/{}", model.name()),
                || {
                    selector.latency_ns(
                        &mut comm,
                        &mut engine,
                        &BcastSpec::new(0, gpus, 128 << 20),
                    )
                },
            );
        }
    }
    bencher.write_report("fig1_intranode").expect("report");
    println!("\npaper reference: 14X / 10.6X / 9.4X / 13X lower latency vs NCCL for 2/4/8/16 GPUs (<=8KB), comparable at large sizes");
}
