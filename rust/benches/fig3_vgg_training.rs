//! Bench F3 — regenerates the paper's Figure 3 (VGG data-parallel
//! training time under CNTK, NCCL-MV2-GDR vs MV2-GDR-Opt, 8–128 GPUs)
//! and extends it with the full-exchange training modes under the
//! compute/comm overlap timeline: for each [`TrainingMode`], iteration
//! time with the barrier model (overlap off) and with layer-wise
//! backprop overlapping the exchange (overlap on).
//!
//! Every table is produced under each link-contention model (FIFO
//! serialized occupancy vs max-min fair share) side by side — the
//! overlap timeline runs many bucket collectives concurrently, so this
//! is where the models diverge most. `LINK_MODEL=fifo|fairshare`
//! restricts a run; the CI gate requires rows for both.
//!
//! `cargo bench --bench fig3_vgg_training`
//! `FIG3_SMOKE=1 cargo bench --bench fig3_vgg_training`  (CI smoke mode:
//! one scale, quick harness; still emits the overlap-on/off × link-model
//! rows the CI gate checks for)
//!
//! Report: `target/reports/fig3_vgg_training.json` — harness rows plus
//! one `fig3/<model>/<gpus>gpus/<mode>/overlap-{off,on}/<linkmodel>` row
//! per (training mode, overlap setting, link model), `mean_ns` carrying
//! the estimated per-iteration time in ns.

use gdrbcast::bench::harness::{link_models_from_env, one_shot_row, Bencher};
use gdrbcast::coordinator::train::{
    estimate_iteration_with_model, estimate_training_iteration_opts, ExchangeOptions,
};
use gdrbcast::coordinator::{BcastBackend, TrainingMode};
use gdrbcast::models::zoo::{googlenet, vgg16};
use gdrbcast::nccl::NcclParams;
use gdrbcast::topology::presets;
use gdrbcast::tuning::Selector;
use gdrbcast::util::json::Json;
use gdrbcast::util::tablefmt::Table;

fn main() {
    let smoke = std::env::var("FIG3_SMOKE").is_ok();
    let nccl = NcclParams::default();
    let mut bencher = if smoke { Bencher::quick() } else { Bencher::new() };
    let mut rows: Vec<Json> = Vec::new();
    let link_models = link_models_from_env();
    let batch_per_gpu = 16; // weak scaling, as the CNTK runs fix per-GPU minibatch
    let scales: &[(usize, usize)] = if smoke {
        &[(1, 8)]
    } else {
        &[(1, 8), (1, 16), (2, 16), (4, 16), (8, 16)]
    };

    for model in [vgg16(), googlenet()] {
        for &lm in &link_models {
            let mut t = Table::new(&[
                "GPUs",
                "NCCL-MV2-GDR s/100it",
                "MV2-GDR-Opt s/100it",
                "improvement",
            ])
            .with_title(format!(
                "Fig. 3 — {} training time ({batch_per_gpu} samples/GPU, weak scaling, {} link model)",
                model.name,
                lm.name()
            ));
            let mut peak = (0usize, 0.0f64);
            for &(nodes, gpn) in scales {
                let cluster = presets::kesch(nodes, gpn).unwrap();
                let batch = batch_per_gpu * cluster.n_gpus();
                let sel = Selector::tuned_with_model(&cluster, None, lm);
                let a = estimate_iteration_with_model(
                    &cluster,
                    &model,
                    &BcastBackend::Mv2Opt(&sel),
                    batch,
                    0.0,
                    lm,
                );
                let b = estimate_iteration_with_model(
                    &cluster,
                    &model,
                    &BcastBackend::NcclMv2(&nccl),
                    batch,
                    0.0,
                    lm,
                );
                let gain = (b.iter_us - a.iter_us) / b.iter_us * 100.0;
                if gain > peak.1 {
                    peak = (cluster.n_gpus(), gain);
                }
                t.row(vec![
                    cluster.n_gpus().to_string(),
                    format!("{:.2}", b.iter_us * 100.0 / 1e6),
                    format!("{:.2}", a.iter_us * 100.0 / 1e6),
                    format!("{gain:.1}%"),
                ]);
            }
            print!("{}", t.render());
            println!(
                "  => [{}] peak improvement {:.1}% at {} GPUs\n",
                lm.name(),
                peak.1,
                peak.0
            );
        }
    }

    // ---- full-exchange training modes, barrier vs overlap timeline ----
    // smoke keeps one node so CI stays fast; the full run reports the
    // paper's 32-GPU application scale
    let (nodes, gpn) = if smoke { (1, 8) } else { (2, 16) };
    let cluster = presets::kesch(nodes, gpn).unwrap();
    let model = vgg16();
    let batch = batch_per_gpu * cluster.n_gpus();
    let gpus = cluster.n_gpus();
    let mut fifo_sel: Option<Selector> = None;
    for &lm in &link_models {
        let sel = Selector::tuned_with_model(&cluster, None, lm);
        let mut t = Table::new(&["mode", "overlap", "compute ms", "exposed comm ms", "iter ms"])
            .with_title(format!(
                "{} full-exchange iteration, {gpus} GPUs — barrier vs overlap ({} link model)",
                model.name,
                lm.name()
            ));
        for mode in [TrainingMode::PartitionedBcast, TrainingMode::AllreduceGradients] {
            for overlap in [false, true] {
                let e = estimate_training_iteration_opts(
                    &cluster,
                    &model,
                    &sel,
                    mode,
                    batch,
                    0.0,
                    ExchangeOptions {
                        overlap,
                        link_model: lm,
                        ..ExchangeOptions::default()
                    },
                );
                let setting = if overlap { "on" } else { "off" };
                t.row(vec![
                    mode.label().to_string(),
                    setting.to_string(),
                    format!("{:.2}", e.compute_us / 1e3),
                    format!("{:.2}", e.comm_us / 1e3),
                    format!("{:.2}", e.iter_us / 1e3),
                ]);
                rows.push(one_shot_row(
                    &format!(
                        "fig3/{}/{}gpus/{}/overlap-{setting}/{}",
                        model.name,
                        gpus,
                        mode.label(),
                        lm.name()
                    ),
                    e.iter_us * 1000.0,
                ));
            }
        }
        print!("{}", t.render());
        println!();
        if lm == gdrbcast::netsim::LinkModel::Fifo {
            fifo_sel = Some(sel);
        }
    }

    // wall-clock of the full iteration estimate (schedule + sim), reusing
    // the loop's FIFO-tuned selector rather than re-running the sweep
    // (only re-tuned when LINK_MODEL restricted the loop to fairshare)
    let sel = fifo_sel.unwrap_or_else(|| Selector::tuned(&cluster));
    bencher.bench(
        &format!("sim/fig3/vgg16/{gpus}gpus/iteration-estimate"),
        || {
            estimate_iteration_with_model(
                &cluster,
                &model,
                &BcastBackend::Mv2Opt(&sel),
                batch,
                0.0,
                gdrbcast::netsim::LinkModel::Fifo,
            )
            .iter_us
        },
    );
    bencher
        .write_report_with("fig3_vgg_training", rows)
        .expect("report");
    println!("\npaper reference: up to 7% faster VGG training at 32 GPUs; matches or beats NCCL-MV2-GDR at every scale");
}
