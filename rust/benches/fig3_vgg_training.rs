//! Bench F3 — regenerates the paper's Figure 3 (VGG data-parallel
//! training time under CNTK, NCCL-MV2-GDR vs MV2-GDR-Opt, 8–128 GPUs).
//!
//! `cargo bench --bench fig3_vgg_training`

use gdrbcast::bench::harness::Bencher;
use gdrbcast::coordinator::train::estimate_iteration;
use gdrbcast::coordinator::BcastBackend;
use gdrbcast::models::zoo::{googlenet, vgg16};
use gdrbcast::nccl::NcclParams;
use gdrbcast::topology::presets;
use gdrbcast::tuning::Selector;
use gdrbcast::util::tablefmt::Table;

fn main() {
    let nccl = NcclParams::default();
    let mut bencher = Bencher::new();
    let batch_per_gpu = 16; // weak scaling, as the CNTK runs fix per-GPU minibatch

    for model in [vgg16(), googlenet()] {
        let mut t = Table::new(&[
            "GPUs",
            "NCCL-MV2-GDR s/100it",
            "MV2-GDR-Opt s/100it",
            "improvement",
        ])
        .with_title(format!(
            "Fig. 3 — {} training time ({batch_per_gpu} samples/GPU, weak scaling)",
            model.name
        ));
        let mut peak = (0usize, 0.0f64);
        for (nodes, gpn) in [(1usize, 8usize), (1, 16), (2, 16), (4, 16), (8, 16)] {
            let cluster = presets::kesch(nodes, gpn);
            let batch = batch_per_gpu * cluster.n_gpus();
            let sel = Selector::tuned(&cluster);
            let a =
                estimate_iteration(&cluster, &model, &BcastBackend::Mv2Opt(&sel), batch, 0.0);
            let b = estimate_iteration(
                &cluster,
                &model,
                &BcastBackend::NcclMv2(&nccl),
                batch,
                0.0,
            );
            let gain = (b.iter_us - a.iter_us) / b.iter_us * 100.0;
            if gain > peak.1 {
                peak = (cluster.n_gpus(), gain);
            }
            t.row(vec![
                cluster.n_gpus().to_string(),
                format!("{:.2}", b.iter_us * 100.0 / 1e6),
                format!("{:.2}", a.iter_us * 100.0 / 1e6),
                format!("{gain:.1}%"),
            ]);
        }
        print!("{}", t.render());
        println!("  => peak improvement {:.1}% at {} GPUs\n", peak.1, peak.0);
    }

    // wall-clock of the full iteration estimate (tuning + schedule + sim)
    let cluster = presets::kesch(2, 16);
    let sel = Selector::tuned(&cluster);
    let model = vgg16();
    let batch = batch_per_gpu * cluster.n_gpus();
    bencher.bench("sim/fig3/vgg16/32gpus/iteration-estimate", || {
        estimate_iteration(&cluster, &model, &BcastBackend::Mv2Opt(&sel), batch, 0.0).iter_us
    });
    bencher.write_report("fig3_vgg_training").expect("report");
    println!("\npaper reference: up to 7% faster VGG training at 32 GPUs; matches or beats NCCL-MV2-GDR at every scale");
}
