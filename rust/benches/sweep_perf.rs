//! Sweep-scale performance benchmark: plan-build throughput, engine
//! execute throughput, templated-vs-rebuild plan acquisition, and full
//! `tune()` wall time at 16/64/128-GPU presets — the numbers DESIGN.md
//! §Perf tracks from PR 2 onward.
//!
//! Emits `target/reports/BENCH_sweep.json` in the standard report shape
//! (an array of `{name, mean_ns, std_dev_ns, p50_ns, p99_ns, iters,
//! samples}` rows; one-shot wall-time measurements appear as single-
//! sample rows, derived throughputs as `*_ops_per_sec` rows, the
//! templated-vs-rebuild ratio as `plan_acquisition/{n}gpus_speedup` and
//! the cache hit rate as `template_cache/{n}gpus_hit_rate` — see
//! DESIGN.md §Measuring).
//!
//! `cargo bench --bench sweep_perf`
//! `SWEEP_PERF_SMOKE=1 cargo bench --bench sweep_perf`  (CI smoke mode)

use std::time::Instant;

use gdrbcast::bench::harness::{link_models_from_env, Bencher};
use gdrbcast::collectives::{self, Algorithm, BcastSpec};
use gdrbcast::coordinator::{RecoveryConfig, RecoveryPolicy};
use gdrbcast::comm::Comm;
use gdrbcast::netsim::{Engine, FaultProfile, LinkModel, OpId, Plan, SimOp};
use gdrbcast::topology::{presets, Cluster};
use gdrbcast::tuning::{montecarlo, persist, space, sweep};
use gdrbcast::util::json::Json;

/// Row-name suffix per link model: FIFO keeps the pre-fair-share names
/// (schema back-compat for report consumers); fair share is tagged.
fn row_suffix(model: LinkModel) -> &'static str {
    match model {
        LinkModel::Fifo => "",
        LinkModel::FairShare => "/fairshare",
    }
}

/// A one-shot wall-time row in the standard report shape.
fn wall_row(name: &str, ns: f64) -> Json {
    gdrbcast::bench::harness::one_shot_row(name, ns)
}

/// The fair-share event-throughput workload: every node runs its own
/// chunked chain broadcast over its GPUs, merged into one plan. Chunks
/// pipeline along each chain (a chunk's hop `i` waits on its hop `i-1`),
/// so each link carries many concurrent flows, and chunk sizes are
/// staggered so departures spread out — lots of arrival/departure events.
/// Crucially the per-node flow sets share no links, so the incremental
/// max-min solver's ripple stays inside one node while the full
/// recompute re-levels the whole cluster on every event.
fn per_node_chain_plan(
    cluster: &Cluster,
    nodes: usize,
    gpn: usize,
    chunks: usize,
    bytes: u64,
) -> Plan {
    let mut plan = Plan::new();
    for node in 0..nodes {
        let base = node * gpn;
        for chunk in 0..chunks {
            let mut left: Option<OpId> = None;
            for i in 0..gpn - 1 {
                let route = cluster
                    .route(
                        cluster.rank_device(base + i),
                        cluster.rank_device(base + i + 1),
                    )
                    .expect("intra-node route");
                let id = plan.push(
                    SimOp::Transfer {
                        route,
                        bytes: bytes + (chunk as u64) * 65536,
                        overhead_ns: 1000,
                        issue_ns: 1000,
                        bw_cap: None,
                    },
                    left,
                    None,
                );
                left = Some(id);
            }
        }
    }
    plan
}

fn main() {
    let smoke = std::env::var("SWEEP_PERF_SMOKE").is_ok();
    let mut bencher = if smoke {
        Bencher::quick()
    } else {
        Bencher::new()
    };
    let mut rows: Vec<Json> = Vec::new();
    let link_models = link_models_from_env();

    // ---- plan-build / engine-execute throughput at 16/64/128 GPUs ------
    for &(nodes, gpn) in &[(1usize, 16usize), (4, 16), (8, 16)] {
        let gpus = nodes * gpn;
        let cluster = presets::kesch(nodes, gpn).unwrap();
        let mut comm = Comm::new(&cluster);
        let bytes: u64 = if smoke { 8 << 20 } else { 64 << 20 };
        let spec = BcastSpec::new(0, gpus, bytes);
        let algo = Algorithm::PipelinedChain { chunk: 512 << 10 };
        let bp = collectives::plan(&algo, &mut comm, &spec);
        let n_ops = bp.plan.len();
        println!("-- kesch({nodes}x{gpn}) = {gpus} GPUs, plan of {n_ops} ops --");

        let r = bencher.bench(&format!("plan/pipelined-chain/{gpus}gpus"), || {
            collectives::plan(&algo, &mut comm, &spec).plan.len()
        });
        let build_ops_per_sec = n_ops as f64 / (r.per_iter.mean / 1e9);
        println!("  plan build: {:.2}M ops/s", build_ops_per_sec / 1e6);
        rows.push(wall_row(
            &format!("plan/{gpus}gpus_ops_per_sec"),
            build_ops_per_sec,
        ));

        for &model in &link_models {
            let sfx = row_suffix(model);
            let mut engine = Engine::with_model(&cluster, model);
            let r = bencher.bench(
                &format!("execute/pipelined-chain/{gpus}gpus{sfx}"),
                || engine.makespan_ns(&bp.plan),
            );
            let exec_ops_per_sec = n_ops as f64 / (r.per_iter.mean / 1e9);
            println!(
                "  engine execute [{}]: {:.2}M ops/s",
                model.name(),
                exec_ops_per_sec / 1e6
            );
            rows.push(wall_row(
                &format!("execute/{gpus}gpus_ops_per_sec{sfx}"),
                exec_ops_per_sec,
            ));
        }
    }

    // ---- plan acquisition: templated vs rebuild-per-point (64 GPUs) ----
    // The tuning sweep's cost model: acquiring every broadcast candidate
    // at every grid size. "rebuild" pays full plan construction per
    // point (the pre-template world, plus the now-unconditional byte-
    // role recording — one Vec per plan, a sliver of the per-op send
    // work); "templated" goes through the comm's template cache, so the
    // size axis rescales byte counts in place. The acceptance bar is
    // ≥ 3× at the 64-GPU preset; the recorded ratio is gated >= 1x in CI
    // (templated slower than rebuild would be an outright regression —
    // both sides run on the same runner, so the ratio is noise-robust).
    {
        let cluster = presets::kesch(4, 16).unwrap();
        let gpus = cluster.n_gpus();
        let acq_sizes: Vec<u64> = if smoke {
            vec![4, 64 << 10, 1 << 20, 16 << 20]
        } else {
            sweep::default_sizes()
        };
        let mut comm = Comm::new(&cluster);
        let r = bencher.bench(&format!("plan_acquisition/rebuild/{gpus}gpus"), || {
            let mut total = 0usize;
            for &bytes in &acq_sizes {
                for algo in space::candidates(bytes) {
                    let spec = BcastSpec::new(0, gpus, bytes);
                    total += collectives::plan(&algo, &mut comm, &spec).plan.len();
                }
            }
            total
        });
        let rebuild_ns = r.per_iter.mean;
        let r = bencher.bench(&format!("plan_acquisition/templated/{gpus}gpus"), || {
            let mut total = 0usize;
            for &bytes in &acq_sizes {
                for algo in space::candidates(bytes) {
                    let spec = BcastSpec::new(0, gpus, bytes);
                    total += collectives::cached_plan(&algo, &mut comm, &spec).plan.len();
                }
            }
            total
        });
        let templated_ns = r.per_iter.mean;
        let speedup = rebuild_ns / templated_ns.max(1.0);
        let (hits, misses) = comm.template_cache().stats();
        let hit_rate = hits as f64 / ((hits + misses).max(1)) as f64;
        println!(
            "plan acquisition at {gpus} GPUs over {} sizes: rebuild {:.2} ms vs templated {:.2} ms = {speedup:.1}x (cache hit rate {hit_rate:.3})",
            acq_sizes.len(),
            rebuild_ns / 1e6,
            templated_ns / 1e6,
        );
        rows.push(wall_row(
            &format!("plan_acquisition/{gpus}gpus_speedup"),
            speedup,
        ));
        rows.push(wall_row(
            &format!("template_cache/{gpus}gpus_hit_rate"),
            hit_rate,
        ));
    }

    // ---- fair-share event throughput: incremental vs full recompute ----
    // The wave-2 acceptance number: events/s through the fair-share loop
    // with the incremental max-min solver vs the full-recompute
    // reference (same engine, flipped via set_full_recompute — the
    // FAIRSHARE_FULL_RECOMPUTE env var sets the same default). The
    // `incremental_vs_full` ratio is gated >= 1x in CI.
    for &(nodes, gpn) in &[(4usize, 16usize), (8, 16)] {
        let cluster = presets::kesch(nodes, gpn).unwrap();
        let chunks = if smoke { 8 } else { 32 };
        let plan = per_node_chain_plan(&cluster, nodes, gpn, chunks, 1 << 20);
        // every op is a flow: one arrival + one departure event each
        let events = 2 * plan.len();
        let mut engine = Engine::with_model(&cluster, LinkModel::FairShare);
        engine.set_full_recompute(false);
        let r = bencher.bench(&format!("engine_events/kesch{nodes}x16/incremental"), || {
            engine.makespan_ns(&plan)
        });
        let inc_ns = r.per_iter.mean;
        let (inc_solves, _) = engine.fairshare_solve_counts();
        assert!(
            inc_solves > 0,
            "incremental solver never engaged on the events workload"
        );
        engine.set_full_recompute(true);
        let r = bencher.bench(&format!("engine_events/kesch{nodes}x16/full"), || {
            engine.makespan_ns(&plan)
        });
        let full_ns = r.per_iter.mean;
        let inc_eps = events as f64 / (inc_ns / 1e9);
        let full_eps = events as f64 / (full_ns / 1e9);
        let ratio = full_ns / inc_ns.max(1.0);
        println!(
            "fair-share events kesch({nodes}x{gpn}): {:.2}M ev/s incremental vs {:.2}M ev/s full ({ratio:.2}x)",
            inc_eps / 1e6,
            full_eps / 1e6
        );
        rows.push(wall_row(
            &format!("engine_events/kesch{nodes}x16/fairshare_events_per_sec"),
            inc_eps,
        ));
        rows.push(wall_row(
            &format!("engine_events/kesch{nodes}x16/fairshare_full_events_per_sec"),
            full_eps,
        ));
        rows.push(wall_row(
            &format!("engine_events/kesch{nodes}x16_incremental_vs_full"),
            ratio,
        ));
    }

    // ---- full tune() wall time: parallel vs the serial reference -------
    // kesch(2, 8) is the acceptance-criteria preset; smoke mode shrinks
    // the size grid but keeps the shape.
    let sizes = if smoke {
        vec![4u64, 64 << 10, 1 << 20, 16 << 20]
    } else {
        sweep::default_sizes()
    };
    let tune_presets: &[(usize, usize)] = if smoke {
        &[(2, 8)]
    } else {
        &[(1, 16), (2, 8), (4, 16), (8, 16)]
    };
    for &(nodes, gpn) in tune_presets {
        let gpus = nodes * gpn;
        let cluster = presets::kesch(nodes, gpn).unwrap();

        for &model in &link_models {
            let sfx = row_suffix(model);
            let t0 = Instant::now();
            let par = sweep::tune_with_model(&cluster, &sizes, None, model);
            let par_ns = t0.elapsed().as_nanos() as f64;

            let t0 = Instant::now();
            let ser = sweep::tune_serial_with_model(&cluster, &sizes, model);
            let ser_ns = t0.elapsed().as_nanos() as f64;

            assert_eq!(
                persist::to_json(&par),
                persist::to_json(&ser),
                "parallel tune diverged from serial at {gpus} GPUs ({})",
                model.name()
            );
            println!(
                "tune kesch({nodes}x{gpn}) [{}] over {} sizes: parallel {:.2}s, serial {:.2}s ({:.2}x)",
                model.name(),
                sizes.len(),
                par_ns / 1e9,
                ser_ns / 1e9,
                ser_ns / par_ns
            );
            rows.push(wall_row(&format!("tune/parallel/{gpus}gpus_wall{sfx}"), par_ns));
            rows.push(wall_row(&format!("tune/serial/{gpus}gpus_wall{sfx}"), ser_ns));
        }
    }

    // ---- datacenter-scale fabrics: plan build + makespan at 1k–64k -----
    // The structured-fabric acceptance rows: a chain broadcast planned
    // and executed on multi-rail fat-trees of 1k/8k/64k GPUs. Every
    // route comes from the algebraic resolver, so the route table only
    // holds the n-1 chain pairs — asserted below, because a dense
    // O(n^2) table at 64k would be ~4B entries and the resolver's whole
    // point is never materializing one. Smoke mode runs the 1k shape
    // only (CI gates `scale_perf/1kgpus/plan_build_ns` against the
    // snapshot); the full run adds the 8k and 64k shapes.
    let scale_shapes: &[(&str, usize, usize, usize)] = if smoke {
        &[("1k", 4, 8, 32)]
    } else {
        &[("1k", 4, 8, 32), ("8k", 8, 16, 64), ("64k", 32, 64, 32)]
    };
    for &(tag, pods, leaves, gpl) in scale_shapes {
        let cluster = presets::fat_tree(pods, leaves, gpl, 2, 2).unwrap();
        let gpus = cluster.n_gpus();
        let mut comm = Comm::new(&cluster);
        let spec = BcastSpec::new(0, gpus, 1 << 20);
        let t0 = Instant::now();
        let bp = collectives::plan(&Algorithm::Chain, &mut comm, &spec);
        let build_ns = t0.elapsed().as_nanos() as f64;
        let n_routes = cluster.routes().n_routes();
        assert!(
            n_routes <= 4 * gpus,
            "route table grew superlinearly at {gpus} GPUs: {n_routes} routes"
        );
        let mut engine = Engine::with_model(&cluster, LinkModel::Fifo);
        let makespan = engine.makespan_ns(&bp.plan);
        println!(
            "scale fat-tree {tag} ({gpus} GPUs): plan build {:.2} ms, {} ops, makespan {:.3} ms, {n_routes} routes interned",
            build_ns / 1e6,
            bp.plan.len(),
            makespan as f64 / 1e6
        );
        rows.push(wall_row(
            &format!("scale_perf/{tag}gpus/plan_build_ns"),
            build_ns,
        ));
        rows.push(wall_row(
            &format!("scale_perf/{tag}gpus/makespan_ns"),
            makespan as f64,
        ));
    }

    // ---- fault Monte Carlo smoke (FAULT_SMOKE=1) -----------------------
    // Not a throughput number: a seeded fault sweep on the acceptance
    // preset whose p50/p99/delivered rows land in the report so CI can
    // pin (a) the rows exist under both link models and (b) the run is
    // deterministic — two back-to-back sweeps must be byte-identical
    // (`fault_sweep/determinism` is 1.0 iff they are).
    if std::env::var("FAULT_SMOKE").is_ok() {
        let cluster = presets::kesch(2, 8).unwrap();
        let profile =
            FaultProfile::parse("kill=1@500us,degrade=2:0.5@200us,straggle=1:3,jitter=0.05")
                .expect("fault profile");
        let mc_algos = [Algorithm::Chain, Algorithm::Knomial { k: 2 }];
        let mc_sizes = [64u64 << 10, 4 << 20];
        let mut deterministic = true;
        for &model in &link_models {
            let sfx = row_suffix(model);
            let cfg = montecarlo::McConfig {
                trials: 6,
                seed: 0x5eed,
                link_model: model,
                threads: None,
            };
            let mc = montecarlo::run(&cluster, &mc_algos, &mc_sizes, &profile, &cfg)
                .expect("profile indices fit the smoke preset");
            let rerun = montecarlo::run(&cluster, &mc_algos, &mc_sizes, &profile, &cfg)
                .expect("profile indices fit the smoke preset");
            deterministic &= mc == rerun;
            for row in &mc {
                let base = format!("fault_sweep/{}/{}{sfx}", row.algorithm, row.bytes);
                println!(
                    "  fault sweep [{}] {} @ {} B: {}/{} delivered",
                    model.name(),
                    row.algorithm,
                    row.bytes,
                    row.delivered,
                    row.trials
                );
                if let Some(s) = &row.stats {
                    rows.push(wall_row(&format!("{base}/p50"), s.p50_ns));
                    rows.push(wall_row(&format!("{base}/p99"), s.p99_ns));
                }
                rows.push(wall_row(
                    &format!("{base}/delivered_frac"),
                    row.delivered_frac(),
                ));
            }
        }
        println!("  fault sweep deterministic across reruns: {deterministic}");
        rows.push(wall_row(
            "fault_sweep/determinism",
            if deterministic { 1.0 } else { 0.0 },
        ));

        // ---- recovery-policy smoke -----------------------------------
        // (a) a rank-isolating kill at t = 0 with a zero retry budget:
        // `none` aborts every trial while the recovering policies finish
        // the job — pinning the `recovery_sweep/<policy>/{p50,p99,
        // aborted_frac}` rows CI gates; (b) a zero-fault baseline where
        // every policy runs the identical healthy job — CI asserts
        // replan's p99 does not exceed restart's there (a policy must
        // cost nothing when nothing fails).
        let victim = cluster.rank_device(cluster.n_gpus() - 1);
        let kills: Vec<String> = cluster
            .links()
            .iter()
            .filter(|l| l.src == victim || l.dst == victim)
            .map(|l| format!("link={}:0.0@0", l.id.0))
            .collect();
        let fatal = FaultProfile::parse(&format!("{},retry=0,timeout=100us", kills.join(",")))
            .expect("rank-isolating profile");
        let zero_fault = FaultProfile::parse("").expect("empty profile");
        let policies = [
            RecoveryConfig::default(),
            RecoveryConfig::with_policy(RecoveryPolicy::Replan),
            RecoveryConfig::with_policy(RecoveryPolicy::Shrink),
            RecoveryConfig::with_policy(RecoveryPolicy::Restart {
                restore_ns: gdrbcast::coordinator::recovery::DEFAULT_RESTORE_NS,
            }),
        ];
        let rcfg = montecarlo::McConfig {
            trials: 4,
            seed: 0x5eed,
            link_model: LinkModel::Fifo,
            threads: Some(1),
        };
        for (prefix, profile) in [
            ("recovery_sweep", &fatal),
            ("recovery_sweep/zero_fault", &zero_fault),
        ] {
            let rrows = montecarlo::recovery_run(
                &cluster,
                &Algorithm::Chain,
                64 << 10,
                4,
                &policies,
                profile,
                &rcfg,
            )
            .expect("recovery sweep on the smoke preset");
            for row in &rrows {
                let base = format!("{prefix}/{}", row.policy);
                println!(
                    "  recovery sweep {base}: {}/{} completed, {} recoveries",
                    row.completed, row.trials, row.recoveries
                );
                if let Some(s) = &row.stats {
                    rows.push(wall_row(&format!("{base}/p50"), s.p50_ns));
                    rows.push(wall_row(&format!("{base}/p99"), s.p99_ns));
                }
                rows.push(wall_row(
                    &format!("{base}/aborted_frac"),
                    row.aborted_frac(),
                ));
            }
        }
    }

    // ---- verifier overhead on the measured path ------------------------
    // The static-verification hooks compile to no-ops outside debug
    // builds, so on the bench path this row must read exactly 0; CI
    // gates on it to catch the hooks ever leaking into release.
    rows.push(wall_row(
        "verify/debug_overhead_ns",
        gdrbcast::analysis::verify_time_ns() as f64,
    ));

    // ---- write BENCH_sweep.json (bencher rows + wall rows) -------------
    let path = bencher
        .write_report_with("BENCH_sweep", rows)
        .expect("write report");
    println!("report: {}", path.display());
}
