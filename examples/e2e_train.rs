//! END-TO-END DRIVER (experiment X2): all three layers composed.
//!
//! * Layer-1/2: the AOT-compiled JAX+Pallas VGG-mini training step runs
//!   on the PJRT CPU client (real gradients, real loss);
//! * Layer-3: the coordinator drives K data-parallel workers on synthetic
//!   CIFAR-shaped shards, averaging gradient shards each iteration, while
//!   the *parameter broadcast* of every iteration is costed on the
//!   simulated KESCH fabric under both comm backends (MV2-GDR-Opt vs
//!   NCCL-MV2-GDR).
//!
//! Run `make artifacts` first, then:
//!
//! ```sh
//! cargo run --release --example e2e_train [-- --iters 300 --workers 4]
//! ```
//!
//! The loss curve + timing split land in target/reports/e2e_train.csv
//! and are recorded in EXPERIMENTS.md.

use gdrbcast::coordinator::{run_serial, BcastBackend, SgdConfig};
use gdrbcast::models::{bcast_messages, zoo::vgg_mini, MessageSchedule};
use gdrbcast::nccl::NcclParams;
use gdrbcast::netsim::Engine;
use gdrbcast::runtime::{Artifacts, PjrtWorker, Runtime, TrainStep};
use gdrbcast::topology::presets;
use gdrbcast::tuning::Selector;
use gdrbcast::util::cli::Args;

fn main() {
    let mut args = Args::from_env();
    let iters = args.opt_or("--iters", 300usize).unwrap();
    let workers = args.opt_or("--workers", 4usize).unwrap();
    args.finish().unwrap();

    // ---- layer 1+2: load the AOT artifact -------------------------------
    let artifacts = match Artifacts::discover() {
        Ok(a) => a,
        Err(e) => {
            eprintln!("{e}");
            std::process::exit(1);
        }
    };
    let rt = Runtime::cpu().expect("PJRT CPU client");
    println!(
        "PJRT platform: {} ({} devices); artifact: {} params, batch {}",
        rt.platform(),
        rt.device_count(),
        artifacts.meta.n_params,
        artifacts.meta.batch
    );
    let step = TrainStep::load(&rt, &artifacts).expect("compile train_step.hlo.txt");

    // ---- layer 3: simulated fabric + tuned broadcast ---------------------
    // the data-parallel job runs on one KESCH node with `workers` GPUs
    let cluster = presets::kesch(1, workers.max(2).min(16));
    let selector = Selector::tuned(&cluster);
    let nccl = NcclParams::default();
    let model = vgg_mini();
    assert_eq!(
        model.total_params() as usize, artifacts.meta.n_params,
        "zoo descriptor and AOT artifact must agree"
    );
    let msgs = bcast_messages(&model, cluster.n_gpus(), MessageSchedule::Partitioned);
    let mut comm = gdrbcast::comm::Comm::new(&cluster);
    let mut engine = Engine::new(&cluster);
    let comm_mv2 = gdrbcast::coordinator::comm_time_ns(
        &mut comm,
        &mut engine,
        &BcastBackend::Mv2Opt(&selector),
        &msgs,
    );
    let comm_nccl = gdrbcast::coordinator::comm_time_ns(
        &mut comm,
        &mut engine,
        &BcastBackend::NcclMv2(&nccl),
        &msgs,
    );

    // ---- the training loop ----------------------------------------------
    let mut params: Vec<f32> = {
        let mut rng = gdrbcast::util::rng::Rng::new(0xC1FA2);
        (0..step.n_params)
            .map(|_| (rng.next_f64() as f32 - 0.5) * 0.05)
            .collect()
    };
    let mut backends: Vec<Box<PjrtWorker>> = (0..workers)
        .map(|w| Box::new(PjrtWorker::new(&step, 1000 + w as u64, 1)))
        .collect();
    println!(
        "training vgg-mini ({} params) for {iters} iterations on {workers} data-parallel workers…",
        step.n_params
    );
    let t0 = std::time::Instant::now();
    let metrics = run_serial(
        &mut params,
        &mut backends,
        &SgdConfig {
            // the AOT step internally applies lr=0.05 and the worker
            // recovers the true gradient; the leader re-applies the
            // *averaged* gradient at the same rate (synchronous SGD)
            lr: 0.05,
            iterations: iters,
        },
        |_| comm_mv2,
    );
    let wall = t0.elapsed();

    // ---- report -----------------------------------------------------------
    println!(
        "done in {:.1}s wall ({:.1} ms compute/iter measured)",
        wall.as_secs_f64(),
        metrics.total_compute_ns() as f64 / iters as f64 / 1e6
    );
    println!(
        "loss: {:.4} -> {:.4}   curve: {}",
        metrics.first_loss(),
        metrics.final_loss(),
        metrics.loss_sparkline(60)
    );
    assert!(
        metrics.loss_decreased(),
        "E2E training must reduce the loss"
    );
    println!(
        "simulated per-iteration parameter broadcast on {}: MV2-GDR-Opt {:.1} us vs NCCL-MV2-GDR {:.1} us ({:.1}x)",
        cluster.name,
        comm_mv2 as f64 / 1e3,
        comm_nccl as f64 / 1e3,
        comm_nccl as f64 / comm_mv2 as f64
    );
    let compute_us = metrics.total_compute_ns() as f64 / iters as f64 / 1e3;
    println!(
        "iteration split (measured compute + simulated comm): {:.0} us + {:.1} us -> comm is {:.2}% of an iteration under MV2-GDR-Opt",
        compute_us,
        comm_mv2 as f64 / 1e3,
        comm_mv2 as f64 / 1e3 / (compute_us + comm_mv2 as f64 / 1e3) * 100.0
    );

    let _ = std::fs::create_dir_all("target/reports");
    std::fs::write("target/reports/e2e_train.csv", metrics.to_csv())
        .expect("write loss curve");
    println!("loss curve written to target/reports/e2e_train.csv");
}
