//! The DNN message-size story (§V-D): what each model's parameter
//! exchange looks like to the broadcast layer, and how the tuned runtime
//! routes each message class.
//!
//! ```sh
//! cargo run --release --example model_zoo
//! ```

use gdrbcast::models::{self, bcast_messages, MessageSchedule};
use gdrbcast::topology::presets;
use gdrbcast::tuning::Selector;
use gdrbcast::util::bytes::format_size;
use gdrbcast::util::tablefmt::Table;

fn main() {
    let mut t = Table::new(&[
        "model",
        "params",
        "bytes",
        "msg @32 ranks",
        "msg @128 ranks",
        "class @128",
    ])
    .with_title("CNTK-partitioned broadcast message sizes by model and scale");
    for name in ["lenet5", "googlenet", "resnet50", "alexnet", "vgg16"] {
        let m = models::by_name(name).unwrap();
        let at32 = bcast_messages(&m, 32, MessageSchedule::Partitioned)[0].bytes;
        let at128 = bcast_messages(&m, 128, MessageSchedule::Partitioned)[0].bytes;
        let class = if at128 <= 8 << 10 {
            "small"
        } else if at128 <= 512 << 10 {
            "medium"
        } else {
            "large"
        };
        t.row(vec![
            m.name.clone(),
            m.total_params().to_string(),
            format_size(m.total_bytes()),
            format_size(at32),
            format_size(at128),
            class.to_string(),
        ]);
    }
    print!("{}", t.render());
    println!(
        "\n§V-D: VGG stays large-message even at 128 ranks; GoogLeNet drops into the\n\
         small/medium band where the proposed designs shine — \"we expect the benefits\n\
         to increase for other models like GoogLeNet\".\n"
    );

    // show which algorithm the tuned table assigns to each model's
    // messages on a 2-node cluster
    let cluster = presets::kesch(2, 16);
    let sel = Selector::tuned(&cluster);
    let mut t2 = Table::new(&["model", "message", "tuned algorithm"])
        .with_title("tuned dispatch for per-model messages (32 ranks, 2 KESCH nodes)");
    for name in ["lenet5", "googlenet", "resnet50", "alexnet", "vgg16"] {
        let m = models::by_name(name).unwrap();
        let msg = bcast_messages(&m, 32, MessageSchedule::Partitioned)[0].bytes;
        t2.row(vec![
            m.name.clone(),
            format_size(msg),
            sel.algorithm(msg).name(),
        ]);
    }
    print!("{}", t2.render());
}
