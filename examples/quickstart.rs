//! Quickstart: build a cluster, tune it, broadcast, compare with NCCL.
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use gdrbcast::collectives::{self, Algorithm, BcastSpec};
use gdrbcast::comm::Comm;
use gdrbcast::nccl::{bcast as nccl_bcast, NcclParams};
use gdrbcast::netsim::Engine;
use gdrbcast::topology::presets;
use gdrbcast::tuning::Selector;
use gdrbcast::util::bytes::{format_size, format_us};

fn main() {
    // 1. A single KESCH node with 8 GPUs (the paper's testbed, Fig. 1c).
    let cluster = presets::kesch(1, 8);
    println!("{}", cluster.describe());

    // 2. The tuned runtime — MV2-GDR-Opt — picks per message size.
    let selector = Selector::tuned(&cluster);
    println!("{}", selector.table().render());

    // 3. Compare one broadcast across designs.
    let mut comm = Comm::new(&cluster);
    let mut engine = Engine::new(&cluster);
    let nccl = NcclParams::default();
    println!("broadcast of GPU buffers across 8 GPUs:");
    for bytes in [4u64, 8 << 10, 1 << 20, 64 << 20] {
        let spec = BcastSpec::new(0, 8, bytes);
        let tuned = selector.latency_ns(&mut comm, &mut engine, &spec);
        let binomial = collectives::latency_ns(
            &Algorithm::Knomial { k: 2 },
            &mut comm,
            &mut engine,
            &spec,
        );
        let pipelined = collectives::latency_ns(
            &Algorithm::PipelinedChain { chunk: 1 << 20 },
            &mut comm,
            &mut engine,
            &spec,
        );
        let nccl_bp = nccl_bcast::plan_intranode(&cluster, &nccl, &spec);
        let nccl_t = engine.execute(&nccl_bp.plan).makespan;
        println!(
            "  {:>6}:  MV2-GDR-Opt {:>10} us [{}]  binomial {:>10} us  pipelined-chain {:>10} us  NCCL {:>10} us",
            format_size(bytes),
            format_us(tuned as f64),
            selector.algorithm(bytes).name(),
            format_us(binomial as f64),
            format_us(pipelined as f64),
            format_us(nccl_t as f64),
        );
    }

    // 4. The paper's headline: how much faster than NCCL at small sizes?
    let spec = BcastSpec::new(0, 8, 4);
    let tuned = selector.latency_ns(&mut comm, &mut engine, &spec);
    let nccl_bp = nccl_bcast::plan_intranode(&cluster, &nccl, &spec);
    let nccl_t = engine.execute(&nccl_bp.plan).makespan;
    println!(
        "\n4-byte broadcast: MV2-GDR-Opt is {:.1}x faster than NCCL ({} vs {} us)",
        nccl_t as f64 / tuned as f64,
        format_us(tuned as f64),
        format_us(nccl_t as f64)
    );
}
