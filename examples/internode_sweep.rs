//! Figure 2 reproduction: internode broadcast latency, NCCL-integrated
//! MVAPICH2 (NCCL-MV2-GDR) vs MV2-GDR-Opt, across KESCH nodes
//! (16 GPUs/node × 2/4/8 nodes = 32/64/128 GPUs).
//!
//! ```sh
//! cargo run --release --example internode_sweep [-- --nodes 2,4,8 --max 128M]
//! ```

use gdrbcast::bench::osu::osu_bcast;
use gdrbcast::bench::report::Figure;
use gdrbcast::collectives::BcastSpec;
use gdrbcast::comm::Comm;
use gdrbcast::nccl::{hierarchical, NcclParams};
use gdrbcast::netsim::Engine;
use gdrbcast::topology::presets;
use gdrbcast::tuning::Selector;
use gdrbcast::util::bytes::{parse_size, pow2_sweep};
use gdrbcast::util::cli::Args;

fn main() {
    let mut args = Args::from_env();
    let node_counts: Vec<usize> = args
        .opt_list("--nodes")
        .unwrap()
        .unwrap_or_else(|| vec![2, 4, 8]);
    let max = parse_size(&args.opt("--max").unwrap_or_else(|| "128M".into())).unwrap();
    let iters = args.opt_or("--iters", 3usize).unwrap();
    args.finish().unwrap();

    let sizes = pow2_sweep(4, max);
    let nccl_params = NcclParams::default();

    for &nodes in &node_counts {
        let cluster = presets::kesch(nodes, 16);
        let gpus = cluster.n_gpus();
        let selector = Selector::tuned(&cluster);
        let mut comm = Comm::new(&cluster);
        let mut engine = Engine::new(&cluster);

        let nccl_res = osu_bcast(&mut engine, &sizes, iters, 1, |bytes, _| {
            hierarchical::plan(
                &mut comm,
                &nccl_params,
                &BcastSpec::new(0, gpus, bytes),
                hierarchical::DEFAULT_CHUNK,
            )
        });
        let mv2_res = osu_bcast(&mut engine, &sizes, iters, 1, |bytes, _| {
            selector.plan(&mut comm, &BcastSpec::new(0, gpus, bytes))
        });

        let mut fig = Figure::new(
            format!("Fig. 2 — internode bcast latency, {gpus} GPUs ({nodes} KESCH nodes)"),
            sizes.clone(),
        );
        fig.push_series(
            "NCCL-MV2-GDR",
            nccl_res.iter().map(|r| r.latency_us).collect(),
        );
        fig.push_series(
            "MV2-GDR-Opt",
            mv2_res.iter().map(|r| r.latency_us).collect(),
        );
        print!("{}", fig.render());
        if let Some((at, ratio)) = fig.max_ratio_below(8 << 10) {
            println!(
                "  small/medium-message improvement: up to {ratio:.1}x (at {at} bytes; paper: 16.4X @64 GPUs, 16.6X @128 GPUs)"
            );
        }
        if let Some(r) = fig.ratio_at_max() {
            println!("  at largest size: NCCL-MV2/MV2 ratio {r:.2} (paper: comparable)\n");
        }
        let _ = std::fs::create_dir_all("target/reports");
        let _ = std::fs::write(
            format!("target/reports/fig2_internode_{gpus}gpus.json"),
            fig.to_json().to_string_pretty(),
        );
    }
}
