//! Figure 1 reproduction: intranode broadcast latency, NCCL vs
//! MV2-GDR-Opt, on one KESCH node with 2/4/8/16 GPUs across the full
//! message range (the osu_bcast methodology).
//!
//! ```sh
//! cargo run --release --example intranode_sweep [-- --gpus 2,4,8,16 --max 128M]
//! ```

use gdrbcast::bench::osu::osu_bcast;
use gdrbcast::bench::report::Figure;
use gdrbcast::collectives::BcastSpec;
use gdrbcast::comm::Comm;
use gdrbcast::nccl::{bcast as nccl_bcast, NcclParams};
use gdrbcast::netsim::Engine;
use gdrbcast::topology::presets;
use gdrbcast::tuning::Selector;
use gdrbcast::util::bytes::{parse_size, pow2_sweep};
use gdrbcast::util::cli::Args;

fn main() {
    let mut args = Args::from_env();
    let gpu_counts: Vec<usize> = args
        .opt_list("--gpus")
        .unwrap()
        .unwrap_or_else(|| vec![2, 4, 8, 16]);
    let max = parse_size(&args.opt("--max").unwrap_or_else(|| "128M".into())).unwrap();
    let iters = args.opt_or("--iters", 5usize).unwrap();
    args.finish().unwrap();

    let sizes = pow2_sweep(4, max);
    let nccl_params = NcclParams::default();

    for &gpus in &gpu_counts {
        let cluster = presets::kesch(1, gpus);
        let selector = Selector::tuned(&cluster);
        let mut comm = Comm::new(&cluster);
        let mut engine = Engine::new(&cluster);

        let nccl_res = osu_bcast(&mut engine, &sizes, iters, 1, |bytes, _| {
            nccl_bcast::plan_intranode(&cluster, &nccl_params, &BcastSpec::new(0, gpus, bytes))
        });
        let mv2_res = osu_bcast(&mut engine, &sizes, iters, 1, |bytes, _| {
            selector.plan(&mut comm, &BcastSpec::new(0, gpus, bytes))
        });

        let mut fig = Figure::new(
            format!("Fig. 1 — intranode bcast latency, {gpus} GPUs (KESCH node)"),
            sizes.clone(),
        );
        fig.push_series("NCCL", nccl_res.iter().map(|r| r.latency_us).collect());
        fig.push_series(
            "MV2-GDR-Opt",
            mv2_res.iter().map(|r| r.latency_us).collect(),
        );
        print!("{}", fig.render());
        if let Some((at, ratio)) = fig.max_ratio_below(8 << 10) {
            println!(
                "  small/medium-message improvement: up to {ratio:.1}x (at {} bytes; paper: 14X/10.6X/9.4X/13X for 2/4/8/16 GPUs)",
                at
            );
        }
        if let Some(r) = fig.ratio_at_max() {
            println!("  at {}: NCCL/MV2 ratio {r:.2} (paper: comparable)\n", sizes.last().map(|s| gdrbcast::util::bytes::format_size(*s)).unwrap_or_default());
        }
        // machine-readable dump
        let _ = std::fs::create_dir_all("target/reports");
        let _ = std::fs::write(
            format!("target/reports/fig1_intranode_{gpus}gpus.json"),
            fig.to_json().to_string_pretty(),
        );
    }
}
