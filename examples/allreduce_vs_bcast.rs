//! Allreduce vs partitioned-bcast training: the first post-paper
//! workload. The paper's CA-CNTK scheme gathers gradient blocks to
//! per-block owners and broadcasts the updated blocks (§V-D); modern
//! frameworks fuse the gradient vector into buckets and allreduce them.
//! This sweep prices the *full* exchange of both schemes per scale —
//! tuned allreduce (ring vs tree, picked per bucket size by the
//! generalized tuning framework) wins from 32 GPUs up.
//!
//! ```sh
//! cargo run --release --example allreduce_vs_bcast [-- --model vgg16 --batch-per-gpu 16]
//! ```

use gdrbcast::collectives::CollectiveKind;
use gdrbcast::coordinator::train::estimate_training_iteration;
use gdrbcast::coordinator::TrainingMode;
use gdrbcast::models::{self, allreduce_buckets, DEFAULT_BUCKET_BYTES};
use gdrbcast::topology::presets;
use gdrbcast::tuning::Selector;
use gdrbcast::util::bytes::{format_size, format_us};
use gdrbcast::util::cli::Args;
use gdrbcast::util::tablefmt::Table;

fn main() {
    let mut args = Args::from_env();
    let model_name = args.opt("--model").unwrap_or_else(|| "vgg16".into());
    let batch_per_gpu = args.opt_or("--batch-per-gpu", 16usize).unwrap();
    args.finish().unwrap();
    let model = models::by_name(&model_name).expect("known model");
    let buckets = allreduce_buckets(&model, DEFAULT_BUCKET_BYTES);
    println!(
        "{}: {} of gradients -> {} allreduce buckets of <= {}",
        model.name,
        format_size(model.total_bytes()),
        buckets.len(),
        format_size(DEFAULT_BUCKET_BYTES)
    );

    let mut t = Table::new(&[
        "GPUs",
        "partitioned-bcast (ms/iter)",
        "allreduce (ms/iter)",
        "exchange speedup",
        "tuned allreduce pick",
    ])
    .with_title(format!(
        "full gradient exchange per training iteration — {} at {batch_per_gpu} samples/GPU",
        model.name
    ));
    let mut first_win: Option<usize> = None;
    // 8 GPUs = half a node; then 1..8 full KESCH nodes
    let scales: Vec<(usize, usize)> = vec![(1, 8), (1, 16), (2, 16), (4, 16), (8, 16)];
    for (nodes, gpn) in scales {
        let cluster = presets::kesch(nodes, gpn);
        let gpus = cluster.n_gpus();
        let batch = batch_per_gpu * gpus;
        let sel = Selector::tuned(&cluster);
        let bcast = estimate_training_iteration(
            &cluster,
            &model,
            &sel,
            TrainingMode::PartitionedBcast,
            batch,
            0.0,
        );
        let ar = estimate_training_iteration(
            &cluster,
            &model,
            &sel,
            TrainingMode::AllreduceGradients,
            batch,
            0.0,
        );
        if ar.iter_us < bcast.iter_us && first_win.is_none() {
            first_win = Some(gpus);
        }
        let pick = sel.algorithm_for(CollectiveKind::Allreduce, buckets[0]);
        t.row(vec![
            gpus.to_string(),
            format!("{:.2}", bcast.iter_us / 1000.0),
            format!("{:.2}", ar.iter_us / 1000.0),
            format!("{:.2}x", bcast.comm_us / ar.comm_us.max(1e-9)),
            pick.name(),
        ]);
    }
    print!("{}", t.render());
    match first_win {
        Some(gpus) => println!("allreduce training wins from {gpus} GPUs up"),
        None => println!("allreduce training never won — check the tuning tables"),
    }

    // the generalized Selector answers per-(collective, bytes) queries
    // for every family the framework models
    let cluster = presets::kesch(2, 16);
    let sel = Selector::tuned(&cluster);
    println!("\ntuned picks on {} ({} ranks):", cluster.name, cluster.n_gpus());
    for kind in CollectiveKind::ALL {
        for bytes in [4u64, 64 << 10, 32 << 20] {
            let algo = sel.algorithm_for(kind, bytes);
            let latency = {
                use gdrbcast::collectives::CollectiveSpec;
                use gdrbcast::comm::Comm;
                use gdrbcast::netsim::Engine;
                let spec =
                    CollectiveSpec::collective(kind, 0, cluster.n_gpus(), bytes);
                let mut comm = Comm::new(&cluster);
                let mut engine = Engine::new(&cluster);
                sel.latency_ns(&mut comm, &mut engine, &spec)
            };
            println!(
                "  {:<16} {:>6}: {:<28} {:>10} us",
                kind.name(),
                format_size(bytes),
                algo.name(),
                format_us(latency as f64)
            );
        }
    }
}
