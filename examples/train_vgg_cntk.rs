//! Figure 3 reproduction: data-parallel VGG training time under the
//! CNTK-style coordinator — NCCL-MV2-GDR vs MV2-GDR-Opt at 8–128 GPUs.
//!
//! ```sh
//! cargo run --release --example train_vgg_cntk [-- --model vgg16 --batch 256]
//! ```

use gdrbcast::coordinator::train::estimate_iteration;
use gdrbcast::coordinator::BcastBackend;
use gdrbcast::models;
use gdrbcast::nccl::NcclParams;
use gdrbcast::topology::presets;
use gdrbcast::tuning::Selector;
use gdrbcast::util::cli::Args;
use gdrbcast::util::tablefmt::Table;

fn main() {
    let mut args = Args::from_env();
    let model_name = args.opt("--model").unwrap_or_else(|| "vgg16".into());
    let batch_per_gpu = args.opt_or("--batch-per-gpu", 16usize).unwrap();
    args.finish().unwrap();
    let model = models::by_name(&model_name).expect("known model");

    let mut t = Table::new(&[
        "GPUs",
        "NCCL-MV2-GDR (s/100 iter)",
        "MV2-GDR-Opt (s/100 iter)",
        "improvement",
    ])
    .with_title(format!(
        "Fig. 3 — {} data-parallel training time (CNTK role), {batch_per_gpu} samples/GPU",
        model.name
    ));
    let nccl = NcclParams::default();
    let mut best_gain = (0usize, 0.0f64);
    // 8 GPUs = half a node; then 1..8 full nodes
    let scales: Vec<(usize, usize)> =
        vec![(1, 8), (1, 16), (2, 16), (4, 16), (8, 16)];
    for (nodes, gpn) in scales {
        let cluster = presets::kesch(nodes, gpn);
        let batch = batch_per_gpu * cluster.n_gpus();
        let sel = Selector::tuned(&cluster);
        let a = estimate_iteration(&cluster, &model, &BcastBackend::Mv2Opt(&sel), batch, 0.0);
        let b = estimate_iteration(
            &cluster,
            &model,
            &BcastBackend::NcclMv2(&nccl),
            batch,
            0.0,
        );
        let gain = (b.iter_us - a.iter_us) / b.iter_us * 100.0;
        if gain > best_gain.1 {
            best_gain = (cluster.n_gpus(), gain);
        }
        t.row(vec![
            cluster.n_gpus().to_string(),
            format!("{:.2}", b.iter_us * 100.0 / 1e6),
            format!("{:.2}", a.iter_us * 100.0 / 1e6),
            format!("{gain:.1}%"),
        ]);
    }
    print!("{}", t.render());
    println!(
        "peak improvement: {:.1}% at {} GPUs (paper: up to 7% at 32 GPUs, matching or beating elsewhere)",
        best_gain.1, best_gain.0
    );
}
