//! The enhanced tuning framework in action: sweep every candidate
//! algorithm across the message range on a chosen cluster, print the
//! per-size leaderboard and the resulting dispatch table, and persist it
//! as a JSON artifact the runtime can load back.
//!
//! ```sh
//! cargo run --release --example tuning_table [-- --nodes 1 --gpus-per-node 16]
//! ```

use gdrbcast::topology::presets;
use gdrbcast::tuning::{persist, sweep};
use gdrbcast::util::bytes::{format_size, format_us};
use gdrbcast::util::cli::Args;

fn main() {
    let mut args = Args::from_env();
    let nodes = args.opt_or("--nodes", 1usize).unwrap();
    let gpn = args.opt_or("--gpus-per-node", 16usize).unwrap();
    let out = args
        .opt("--out")
        .unwrap_or_else(|| "target/reports/tuning_table.json".into());
    args.finish().unwrap();

    let cluster = presets::kesch(nodes, gpn);
    println!("{}", cluster.describe());

    // per-size leaderboards at a few representative sizes
    for bytes in [4u64, 8 << 10, 1 << 20, 64 << 20] {
        let point = sweep::sweep_size(&cluster, bytes, 0);
        println!("candidates at {}:", format_size(bytes));
        for (algo, t) in point.all.iter().take(5) {
            let marker = if *algo == point.winner { " <= tuned pick" } else { "" };
            println!(
                "  {:<28} {:>12} us{}",
                algo.name(),
                format_us(*t as f64),
                marker
            );
        }
    }

    // the full dispatch table
    let table = sweep::tune(&cluster, &sweep::default_sizes());
    println!();
    print!("{}", table.render());

    let path = std::path::PathBuf::from(&out);
    persist::save(&table, &path).expect("persist table");
    println!("persisted to {out}");
    let back = persist::load(&path).expect("load back");
    assert_eq!(back.entries.len(), table.entries.len());
    println!("round-trip verified ({} entries)", back.entries.len());
}
