"""L1 correctness: Pallas kernels vs pure-jnp oracles.

Hypothesis sweeps shapes and dtypes; assert_allclose against ref.py is
THE core correctness signal for the compute layer.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import linear, matmul, ref, sgd

DIMS = st.integers(min_value=1, max_value=96)


def rand(rng, *shape, dtype=np.float32):
    return jnp.asarray(rng.standard_normal(shape).astype(dtype))


@settings(max_examples=25, deadline=None)
@given(m=DIMS, k=DIMS, n=DIMS, seed=st.integers(0, 2**31 - 1))
def test_matmul_matches_ref_f32(m, k, n, seed):
    rng = np.random.default_rng(seed)
    x, y = rand(rng, m, k), rand(rng, k, n)
    out = matmul.matmul(x, y)
    np.testing.assert_allclose(out, ref.matmul(x, y), rtol=1e-4, atol=1e-4)


@settings(max_examples=10, deadline=None)
@given(
    m=st.sampled_from([8, 32, 64]),
    k=st.sampled_from([16, 64, 128]),
    n=st.sampled_from([8, 32, 128]),
    seed=st.integers(0, 2**31 - 1),
)
def test_matmul_matches_ref_bf16(m, k, n, seed):
    rng = np.random.default_rng(seed)
    x = rand(rng, m, k).astype(jnp.bfloat16)
    y = rand(rng, k, n).astype(jnp.bfloat16)
    out = matmul.matmul(x, y)
    np.testing.assert_allclose(
        np.asarray(out, np.float32),
        np.asarray(ref.matmul(x, y), np.float32),
        rtol=0.08,
        atol=0.25,
    )


@settings(max_examples=25, deadline=None)
@given(m=DIMS, k=DIMS, n=DIMS, seed=st.integers(0, 2**31 - 1))
def test_fused_linear_matches_ref(m, k, n, seed):
    rng = np.random.default_rng(seed)
    x, w, b = rand(rng, m, k), rand(rng, k, n), rand(rng, n)
    out = linear.fused_linear(x, w, b)
    np.testing.assert_allclose(
        out, ref.fused_linear(x, w, b), rtol=1e-4, atol=1e-4
    )
    # ReLU: no negatives survive
    assert float(np.min(np.asarray(out))) >= 0.0


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(1, 200_000),
    lr=st.floats(1e-5, 1.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_sgd_update_matches_ref(n, lr, seed):
    rng = np.random.default_rng(seed)
    p, g = rand(rng, n), rand(rng, n)
    out = sgd.sgd_update(p, g, jnp.asarray([lr], jnp.float32))
    np.testing.assert_allclose(
        out, ref.sgd_update(p, g, lr), rtol=1e-5, atol=1e-6
    )


@pytest.mark.parametrize(
    "block", [(32, 32, 32), (64, 128, 64), (128, 128, 128), (16, 8, 128)]
)
def test_matmul_block_shapes_agree(block):
    """Block-shape sweep (the §Perf-L1 tuning axis) never changes values."""
    rng = np.random.default_rng(0)
    x, y = rand(rng, 64, 96), rand(rng, 96, 40)
    out = matmul.matmul(x, y, block=block)
    np.testing.assert_allclose(out, ref.matmul(x, y), rtol=1e-4, atol=1e-4)


def test_matmul_rejects_mismatched_contraction():
    rng = np.random.default_rng(0)
    with pytest.raises(AssertionError):
        matmul.matmul(rand(rng, 4, 5), rand(rng, 6, 7))


def test_vmem_footprint_within_budget():
    """Default tiles must fit VMEM (16 MB/core) with double buffering."""
    fp = matmul.vmem_footprint_bytes()
    assert 2 * fp <= 16 << 20, f"footprint {fp}"


def test_mxu_utilization_full_at_native_tiles():
    assert matmul.mxu_utilization_estimate(512, 512, 512) == 1.0
    assert matmul.mxu_utilization_estimate(512, 512, 10) < 0.2


def test_softmax_xent_ref_sane():
    logits = jnp.asarray([[10.0, 0.0], [0.0, 10.0]], jnp.float32)
    y = jnp.asarray([[1.0, 0.0], [0.0, 1.0]], jnp.float32)
    assert float(ref.softmax_xent(logits, y)) < 1e-3
    y_wrong = jnp.asarray([[0.0, 1.0], [1.0, 0.0]], jnp.float32)
    assert float(ref.softmax_xent(logits, y_wrong)) > 5.0


def test_softmax_xent_stable_for_huge_logits():
    logits = jnp.asarray([[1e4, -1e4]], jnp.float32)
    y = jnp.asarray([[1.0, 0.0]], jnp.float32)
    assert np.isfinite(float(ref.softmax_xent(logits, y)))


def test_kernels_differentiable_via_custom_vjp():
    """The model's custom VJPs route gradients through Pallas matmuls."""
    from compile import model

    rng = np.random.default_rng(1)
    x = rand(rng, 8, 16)
    w = rand(rng, 16, 4)
    b = rand(rng, 4)

    def f(w, b):
        return jnp.sum(model.linear_relu(x, w, b))

    gw, gb = jax.grad(f, argnums=(0, 1))(w, b)

    def f_ref(w, b):
        return jnp.sum(ref.fused_linear(x, w, b))

    gw_ref, gb_ref = jax.grad(f_ref, argnums=(0, 1))(w, b)
    np.testing.assert_allclose(gw, gw_ref, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(gb, gb_ref, rtol=1e-4, atol=1e-4)
