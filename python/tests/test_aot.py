"""AOT path: HLO-text emission sanity."""

import json

import pytest

from compile import aot, model


@pytest.fixture(scope="module")
def train_hlo():
    return aot.lower_train_step()


def test_train_step_lowers_to_hlo_text(train_hlo):
    assert "HloModule" in train_hlo
    # jax wraps in an entry computation with our tuple convention
    assert "ROOT" in train_hlo


def test_hlo_has_expected_parameter_shapes(train_hlo):
    # flat params f32[P], x f32[B,D], y f32[B,C], lr f32[1]
    assert f"f32[{model.N_PARAMS}]" in train_hlo
    assert f"f32[{model.BATCH},{model.INPUT_DIM}]" in train_hlo
    assert f"f32[{model.BATCH},{model.CLASSES}]" in train_hlo


def test_hlo_output_is_tuple_of_flat_array(train_hlo):
    assert f"f32[{model.N_PARAMS + 1}]" in train_hlo


def test_no_custom_calls_surviving(train_hlo):
    """interpret=True must lower Pallas to plain HLO — a Mosaic
    custom-call would be unloadable by the CPU PJRT client."""
    assert "mosaic" not in train_hlo.lower()


def test_meta_is_consistent():
    m = aot.meta()
    assert m["n_params"] == model.N_PARAMS
    assert sum(e["len"] for e in m["layout"]) == model.N_PARAMS
    # json-serialisable
    text = json.dumps(m)
    assert json.loads(text)["batch"] == model.BATCH


def test_predict_lowers():
    hlo = aot.lower_predict()
    assert "HloModule" in hlo
    assert f"f32[{model.BATCH},{model.CLASSES}]" in hlo
