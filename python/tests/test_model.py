"""L2 correctness: the VGG-mini training step."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import model
from compile.kernels import ref


def batch(seed=0, batch=model.BATCH):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.standard_normal((batch, model.INPUT_DIM)), jnp.float32)
    labels = rng.integers(0, model.CLASSES, batch)
    y = jnp.zeros((batch, model.CLASSES), jnp.float32).at[
        jnp.arange(batch), labels
    ].set(1.0)
    return x, y


def test_layout_covers_params_exactly():
    lay = model.layout()
    assert lay[0][1] == 0
    for (_, off, ln), (_, noff, _) in zip(lay, lay[1:]):
        assert off + ln == noff
    assert sum(ln for _, _, ln in lay) == model.N_PARAMS
    assert model.N_PARAMS == 3072 * 512 + 512 + 512 * 256 + 256 + 256 * 10 + 10


def test_flatten_unflatten_roundtrip():
    flat = model.init_params(3)
    back = model.flatten(model.unflatten(flat))
    np.testing.assert_array_equal(np.asarray(flat), np.asarray(back))


def test_forward_shapes():
    flat = model.init_params(0)
    x, _ = batch()
    (logits,) = model.predict(flat, x)
    assert logits.shape == (model.BATCH, model.CLASSES)
    assert np.isfinite(np.asarray(logits)).all()


def test_gradient_matches_pure_jnp_model():
    """The Pallas-kernel model must differentiate identically to a pure
    jnp implementation of the same network."""
    flat = model.init_params(1)
    x, y = batch(1)

    def loss_pure(flat_params):
        params = model.unflatten(flat_params)
        h = x
        for w, b in params[:-1]:
            h = jnp.maximum(jnp.matmul(h, w) + b, 0.0)
        w, b = params[-1]
        return ref.softmax_xent(jnp.matmul(h, w) + b, y)

    g_kernel = jax.grad(model.loss_fn)(flat, x, y)
    g_pure = jax.grad(loss_pure)(flat)
    np.testing.assert_allclose(
        np.asarray(g_kernel), np.asarray(g_pure), rtol=5e-3, atol=1e-5
    )


def test_train_step_reduces_loss():
    flat = model.init_params(5)
    x, y = batch(7)
    lr = jnp.asarray([0.05], jnp.float32)
    losses = []
    step = jax.jit(model.train_step)
    for _ in range(15):
        (out,) = step(flat, x, y, lr)
        flat = out[:-1]
        losses.append(float(out[-1]))
    assert losses[-1] < losses[0] * 0.7, losses


def test_train_step_output_layout():
    flat = model.init_params(2)
    x, y = batch(2)
    (out,) = model.train_step(flat, x, y, jnp.asarray([0.01], jnp.float32))
    assert out.shape == (model.N_PARAMS + 1,)
    # zero lr -> params unchanged
    (out0,) = model.train_step(flat, x, y, jnp.asarray([0.0], jnp.float32))
    np.testing.assert_allclose(np.asarray(out0[:-1]), np.asarray(flat), atol=0)
