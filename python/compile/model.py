"""Layer-2 JAX model: the VGG-mini training step.

A VGG-spirit MLP classifier over 32×32×3 inputs (3072 → 512 → 256 → 10)
— the same "few large FC tensors + tiny biases" parameter signature that
makes VGG the paper's application workload, at a size the CPU PJRT
client trains comfortably in the e2e_train example.

Layer forward/backward both run the Layer-1 Pallas kernels: the fused
linear kernel carries the forward, and a `jax.custom_vjp` expresses the
backward as Pallas matmuls, so the entire hot path lowers through the
kernels. Parameters are a single flat f32 vector (what the rust runtime
holds, and exactly what CNTK-style partitioned broadcast wants), and the
public entry points take/return flat arrays only:

    train_step(flat_params[P], x[B,D], y[B,C], lr[1])
        -> (concat(new_flat_params, [loss]),)
    predict(flat_params[P], x[B,D]) -> (logits[B,C],)
"""

import jax
import jax.numpy as jnp

from .kernels.linear import fused_linear
from .kernels.matmul import matmul
from .kernels.ref import softmax_xent
from .kernels.sgd import sgd_update

# architecture (must stay in sync with meta.json via LAYOUT)
DIMS = (3072, 512, 256, 10)
BATCH = 64
INPUT_DIM = DIMS[0]
CLASSES = DIMS[-1]


def layout():
    """(name, offset, length) slices of the flat parameter vector."""
    out = []
    off = 0
    for i in range(len(DIMS) - 1):
        cin, cout = DIMS[i], DIMS[i + 1]
        out.append((f"fc{i + 1}.w", off, cin * cout))
        off += cin * cout
        out.append((f"fc{i + 1}.b", off, cout))
        off += cout
    return out


N_PARAMS = sum(length for _, _, length in layout())


def unflatten(flat):
    """Flat vector -> [(w, b), ...] pytree."""
    params = []
    off = 0
    for i in range(len(DIMS) - 1):
        cin, cout = DIMS[i], DIMS[i + 1]
        w = flat[off : off + cin * cout].reshape(cin, cout)
        off += cin * cout
        b = flat[off : off + cout]
        off += cout
        params.append((w, b))
    return params


def flatten(params):
    """[(w, b), ...] -> flat vector."""
    return jnp.concatenate(
        [t.reshape(-1) for wb in params for t in wb]
    )


def init_params(seed: int = 0):
    """He-initialised flat parameter vector (host-side, for tests)."""
    key = jax.random.PRNGKey(seed)
    chunks = []
    for i in range(len(DIMS) - 1):
        cin, cout = DIMS[i], DIMS[i + 1]
        key, wk = jax.random.split(key)
        w = jax.random.normal(wk, (cin, cout), jnp.float32) * jnp.sqrt(2.0 / cin)
        chunks.append(w.reshape(-1))
        chunks.append(jnp.zeros((cout,), jnp.float32))
    return jnp.concatenate(chunks)


# ---- kernel-backed layers with custom VJPs --------------------------------


@jax.custom_vjp
def linear_relu(x, w, b):
    return fused_linear(x, w, b)


def _linear_relu_fwd(x, w, b):
    out = fused_linear(x, w, b)
    return out, (x, w, out)


def _linear_relu_bwd(res, dy):
    x, w, out = res
    dz = dy * (out > 0).astype(dy.dtype)
    dx = matmul(dz, w.T)
    dw = matmul(x.T, dz)
    db = jnp.sum(dz, axis=0)
    return dx, dw, db


linear_relu.defvjp(_linear_relu_fwd, _linear_relu_bwd)


@jax.custom_vjp
def dense(x, w, b):
    return matmul(x, w) + b


def _dense_fwd(x, w, b):
    return matmul(x, w) + b, (x, w)


def _dense_bwd(res, dy):
    x, w = res
    dx = matmul(dy, w.T)
    dw = matmul(x.T, dy)
    db = jnp.sum(dy, axis=0)
    return dx, dw, db


dense.defvjp(_dense_fwd, _dense_bwd)


# ---- forward / loss / step -------------------------------------------------


def forward(params, x):
    """Logits for a batch."""
    h = x
    for w, b in params[:-1]:
        h = linear_relu(h, w, b)
    w, b = params[-1]
    return dense(h, w, b)


def loss_fn(flat_params, x, y_onehot):
    params = unflatten(flat_params)
    logits = forward(params, x)
    return softmax_xent(logits, y_onehot)


def train_step(flat_params, x, y_onehot, lr):
    """One SGD step; returns a 1-tuple of concat(new_params, [loss])."""
    loss, grad = jax.value_and_grad(loss_fn)(flat_params, x, y_onehot)
    new_flat = sgd_update(flat_params, grad, lr)
    return (jnp.concatenate([new_flat, loss[None]]),)


def predict(flat_params, x):
    """Logits only (serving path)."""
    return (forward(unflatten(flat_params), x),)
