"""Build-time Python: Layer-2 JAX model + Layer-1 Pallas kernels + AOT
lowering. Never imported on the rust request path."""
