"""Layer-1 Pallas kernel: blocked matmul.

TPU-flavoured tiling (see DESIGN.md §Hardware-Adaptation): the grid walks
(M/bm, N/bn, K/bk); for each output tile the innermost grid dimension
accumulates (bm, bk) x (bk, bn) MXU contractions into the VMEM-resident
output tile. The paper's CUDA-side compute (CNTK's GEMMs) maps onto
thread-block tiles + shared memory; here the same HBM→VMEM schedule is
expressed with BlockSpec ``index_map``s.

Lowered with ``interpret=True`` — the CPU PJRT client cannot execute
Mosaic custom-calls; real-TPU performance is *estimated* from the VMEM
footprint / MXU utilisation analysis in DESIGN.md §Perf and
EXPERIMENTS.md §Perf.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK = (128, 128, 128)  # (bm, bk, bn) — MXU-native 128x128 tiles


def _matmul_kernel(x_ref, y_ref, o_ref, *, n_k: int):
    """One (bm, bn) output tile; grid dim 2 walks the K blocks
    sequentially, accumulating into the VMEM-resident output tile."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    # MXU contraction of one (bm, bk) x (bk, bn) block pair
    o_ref[...] += jnp.dot(
        x_ref[...], y_ref[...], preferred_element_type=jnp.float32
    ).astype(o_ref.dtype)
    del n_k  # shape bookkeeping only; flush happens via out_specs


def _pick_block(dim: int, want: int) -> int:
    """Largest divisor of `dim` that is ≤ want (keeps the grid exact)."""
    b = min(dim, want)
    while dim % b != 0:
        b -= 1
    return b


@functools.partial(jax.jit, static_argnames=("block",))
def matmul(x, y, block=DEFAULT_BLOCK):
    """Blocked Pallas matmul: x[M,K] @ y[K,N] -> [M,N]."""
    m, k = x.shape
    k2, n = y.shape
    assert k == k2, f"contraction mismatch {k} vs {k2}"
    bm = _pick_block(m, block[0])
    bk = _pick_block(k, block[1])
    bn = _pick_block(n, block[2])
    n_k = k // bk
    grid = (m // bm, n // bn, n_k)
    return pl.pallas_call(
        functools.partial(_matmul_kernel, n_k=n_k),
        grid=grid,
        in_specs=[
            # x tile: row block i, K block kk
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            # y tile: K block kk, col block j
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        interpret=True,
    )(x, y)


def vmem_footprint_bytes(block=DEFAULT_BLOCK, dtype_bytes: int = 4) -> int:
    """Estimated VMEM residency per grid step: x-tile + y-tile + output
    tile (doubling for pipelining buffers is the caller's concern). Used
    by the §Perf analysis in EXPERIMENTS.md."""
    bm, bk, bn = block
    return dtype_bytes * (bm * bk + bk * bn + bm * bn)


def mxu_utilization_estimate(m: int, k: int, n: int, block=DEFAULT_BLOCK) -> float:
    """Fraction of MXU-issue slots doing useful work for this problem:
    ratio of real contraction volume to the padded tile volume the grid
    executes. 1.0 when every dimension divides its block."""
    bm = _pick_block(m, block[0])
    bk = _pick_block(k, block[1])
    bn = _pick_block(n, block[2])
    useful = m * k * n
    # tiles are exact divisors by construction, but small dims shrink the
    # tile below the 128x128 MXU native shape -> underutilisation
    eff_m = min(bm, 128) / 128.0
    eff_k = min(bk, 128) / 128.0
    eff_n = min(bn, 128) / 128.0
    del useful
    return eff_m * eff_k * eff_n
