"""Layer-1 Pallas kernel: fused SGD parameter update.

The optimizer step is bandwidth-bound: param and grad stream HBM→VMEM
once, the update is a fused multiply-add on the VPU, and the new param
streams back. Blocked 1-D over the flattened parameter vector.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

BLOCK = 65_536  # 256 KB f32 per tile — comfortably VMEM-resident


def _sgd_kernel(p_ref, g_ref, lr_ref, o_ref):
    lr = lr_ref[0]
    o_ref[...] = (
        p_ref[...].astype(jnp.float32) - lr * g_ref[...].astype(jnp.float32)
    ).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block",))
def sgd_update(param, grad, lr, block=BLOCK):
    """param - lr * grad over flat f32 vectors; lr is shape (1,).

    Arbitrary lengths are zero-padded up to a block multiple (elementwise
    op — padding is free) so the grid stays O(n/block) even for prime n.
    """
    (n,) = param.shape
    assert grad.shape == (n,)
    b = min(block, n)
    pad = (-n) % b
    p = jnp.pad(param, (0, pad)) if pad else param
    g = jnp.pad(grad, (0, pad)) if pad else grad
    grid = ((n + pad) // b,)
    out = pl.pallas_call(
        _sgd_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((b,), lambda i: (i,)),
            pl.BlockSpec((b,), lambda i: (i,)),
            # lr: the same single-element block for every grid step
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((b,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n + pad,), param.dtype),
        interpret=True,
    )(p, g, lr)
    return out[:n] if pad else out
