"""Layer-1 Pallas kernel: fused linear layer — relu(x @ w + b).

Fusing the bias add + ReLU into the matmul epilogue saves one HBM
round-trip of the activation tensor per layer (the standard epilogue
fusion that CUDA kernels get from cuBLASLt; here it is the flush step of
the K-accumulation loop).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .matmul import _pick_block, DEFAULT_BLOCK


def _fused_linear_kernel(x_ref, w_ref, b_ref, o_ref, *, n_k: int):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=jnp.float32
    ).astype(o_ref.dtype)

    # epilogue on the last K block: bias + ReLU in VMEM, single flush
    @pl.when(k == n_k - 1)
    def _epilogue():
        o_ref[...] = jnp.maximum(
            o_ref[...] + b_ref[...].astype(o_ref.dtype), 0.0
        )


@functools.partial(jax.jit, static_argnames=("block",))
def fused_linear(x, w, b, block=DEFAULT_BLOCK):
    """relu(x[M,K] @ w[K,N] + b[N]) -> [M,N]."""
    m, k = x.shape
    k2, n = w.shape
    assert k == k2 and b.shape == (n,)
    bm = _pick_block(m, block[0])
    bk = _pick_block(k, block[1])
    bn = _pick_block(n, block[2])
    n_k = k // bk
    grid = (m // bm, n // bn, n_k)
    return pl.pallas_call(
        functools.partial(_fused_linear_kernel, n_k=n_k),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
            # bias: the j-th column block, broadcast over rows
            pl.BlockSpec((bn,), lambda i, j, kk: (j,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        interpret=True,
    )(x, w, b)
