"""Pure-jnp reference oracles for the Pallas kernels.

Every kernel in this package must match its oracle here (pytest sweeps
shapes/dtypes with hypothesis and asserts allclose). The oracles are the
semantic ground truth; the kernels are the performance implementations.
"""

import jax.numpy as jnp


def matmul(x, y):
    """Plain matmul with f32 accumulation."""
    return jnp.matmul(x, y, preferred_element_type=jnp.float32).astype(x.dtype)


def fused_linear(x, w, b):
    """relu(x @ w + b)."""
    out = jnp.matmul(x, w, preferred_element_type=jnp.float32)
    out = out + b.astype(jnp.float32)
    return jnp.maximum(out, 0.0).astype(x.dtype)


def sgd_update(param, grad, lr):
    """param - lr * grad (lr a scalar)."""
    return (param.astype(jnp.float32) - lr * grad.astype(jnp.float32)).astype(
        param.dtype
    )


def softmax_xent(logits, y_onehot):
    """Mean softmax cross-entropy over the batch (stable log-softmax)."""
    logits = logits.astype(jnp.float32)
    logits = logits - jnp.max(logits, axis=-1, keepdims=True)
    log_z = jnp.log(jnp.sum(jnp.exp(logits), axis=-1, keepdims=True))
    log_probs = logits - log_z
    return -jnp.mean(jnp.sum(y_onehot * log_probs, axis=-1))
