"""Layer-1 Pallas kernels (interpret=True on CPU; see DESIGN.md
§Hardware-Adaptation for the CUDA→TPU mapping)."""

from . import linear, matmul, ref, sgd  # noqa: F401

__all__ = ["linear", "matmul", "ref", "sgd"]
