"""AOT lowering: JAX/Pallas -> HLO text artifacts for the rust runtime.

HLO *text* is the interchange format, not ``HloModuleProto.serialize()``:
jax >= 0.5 emits protos with 64-bit instruction ids that the published
``xla`` crate's xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``);
the text parser reassigns ids and round-trips cleanly. See
/opt/xla-example/README.md.

Usage: ``python -m compile.aot --out ../artifacts`` (from python/).
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_train_step() -> str:
    spec_p = jax.ShapeDtypeStruct((model.N_PARAMS,), jnp.float32)
    spec_x = jax.ShapeDtypeStruct((model.BATCH, model.INPUT_DIM), jnp.float32)
    spec_y = jax.ShapeDtypeStruct((model.BATCH, model.CLASSES), jnp.float32)
    spec_lr = jax.ShapeDtypeStruct((1,), jnp.float32)
    lowered = jax.jit(model.train_step).lower(spec_p, spec_x, spec_y, spec_lr)
    return to_hlo_text(lowered)


def lower_predict() -> str:
    spec_p = jax.ShapeDtypeStruct((model.N_PARAMS,), jnp.float32)
    spec_x = jax.ShapeDtypeStruct((model.BATCH, model.INPUT_DIM), jnp.float32)
    lowered = jax.jit(model.predict).lower(spec_p, spec_x)
    return to_hlo_text(lowered)


def meta() -> dict:
    return {
        "n_params": model.N_PARAMS,
        "batch": model.BATCH,
        "input_dim": model.INPUT_DIM,
        "classes": model.CLASSES,
        "layout": [
            {"name": name, "offset": off, "len": length}
            for name, off, length in model.layout()
        ],
    }


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts", help="artifact directory")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    ts = lower_train_step()
    with open(os.path.join(args.out, "train_step.hlo.txt"), "w") as f:
        f.write(ts)
    print(f"train_step.hlo.txt: {len(ts)} chars")

    pr = lower_predict()
    with open(os.path.join(args.out, "predict.hlo.txt"), "w") as f:
        f.write(pr)
    print(f"predict.hlo.txt: {len(pr)} chars")

    with open(os.path.join(args.out, "meta.json"), "w") as f:
        json.dump(meta(), f, indent=2)
    print(f"meta.json: n_params={model.N_PARAMS}")


if __name__ == "__main__":
    main()
