//! Detects whether the real `xla` crate has been vendored (see the
//! `pjrt` feature in Cargo.toml). The feature flag alone cannot make
//! `runtime/pjrt.rs`'s real client compile in the offline image — the
//! crate simply is not there — so the module is gated on
//! `all(feature = "pjrt", pjrt_vendored)`: feature-complete builds like
//! `clippy --all-features` keep working against the stub until the
//! dependency is actually present.

use std::path::Path;

fn main() {
    println!("cargo::rustc-check-cfg=cfg(pjrt_vendored)");
    println!("cargo::rerun-if-changed=vendor/xla/Cargo.toml");
    if Path::new("vendor/xla/Cargo.toml").exists() {
        println!("cargo::rustc-cfg=pjrt_vendored");
    }
}
