#!/usr/bin/env bash
# Determinism lint for diagnostic paths.
#
# The static verifier (rust/src/analysis) and the plan validator
# (rust/src/collectives/validate.rs) promise byte-identical reports run
# to run. Two things silently break that promise:
#
#   1. std::collections::{HashMap,HashSet} — iteration order depends on
#      a per-process RandomState, so any report built by walking one is
#      nondeterministic. Diagnostic paths use Vec/sort or dense index
#      tables instead.
#   2. bare `+`/`*` on values that can sit at the UNREACHABLE_NS
#      sentinel (SimTime::MAX / 4) — close enough to the top of the
#      range that naive arithmetic overflows; saturating_add /
#      saturating_mul are required.
#
# Escape hatch: append a `det-ok` comment to a line that is a verified
# false positive.
set -euo pipefail
cd "$(dirname "$0")/.."

DIAG_PATHS=(rust/src/analysis rust/src/collectives/validate.rs)
fail=0

hits=$(grep -rn 'HashMap\|HashSet' "${DIAG_PATHS[@]}" | grep -v 'det-ok' || true)
if [ -n "$hits" ]; then
    echo "determinism lint: hash collections in diagnostic paths" >&2
    echo "(iteration order is random per process; use Vec/sort or dense tables):" >&2
    echo "$hits" >&2
    fail=1
fi

# Strip `//` comment tails before matching so prose mentioning
# UNREACHABLE_NS or arithmetic doesn't trip the lint.
hits=$(grep -rn 'UNREACHABLE_NS' "${DIAG_PATHS[@]}" \
    | sed 's@//.*@@' \
    | grep -v 'saturating_\|det-ok' \
    | grep '[+*]' || true)
if [ -n "$hits" ]; then
    echo "determinism lint: bare +/* arithmetic near UNREACHABLE_NS" >&2
    echo "(values at the sentinel overflow; use saturating_add/saturating_mul):" >&2
    echo "$hits" >&2
    fail=1
fi

if [ "$fail" -ne 0 ]; then
    exit 1
fi
echo "determinism lint: clean"
